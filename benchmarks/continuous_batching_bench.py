"""Continuous batching benchmark: iteration-level scheduling vs the
serial chunk loop.

One workload, snapshotted to BENCH_continuous_batching.json: N long
prompts served by the same EPD cluster (smollm reduced, chunked paged
prefill) through both drivers:

1. SERIAL baseline — ``submit()`` + ``run_until_done()`` with the fused
   StreamTimeline: every prefill chunk, KV-transfer exposure, and decode
   step lands on ONE modeled clock, which is exactly what a blocking
   chunk loop pays (prefill request A to completion, transfer, then B,
   ... then decode).

2. CONTINUOUS — ``run_continuous()``: the IterationScheduler interleaves
   prefill chunks across requests on the Prefill stream while admitted
   requests decode on the Decode stream; KV-transfer exposure (handshake
   round-trip latency, not link occupancy) gates each request's decode
   JOIN without blocking either device.

Both drivers execute the same jitted forwards through the same
PrefillTask state machine, so the bench asserts bit-identical greedy
outputs and a leak-free page pool before reporting makespans. The
acceptance gate is modeled speedup >= 1.5x at >= 4 concurrent long
prompts with 0 leaked pages.
"""
from __future__ import annotations

import json
import os
import time
from typing import List

MIN_SPEEDUP = 1.5


def bench_continuous_batching() -> List[str]:
    import jax

    from repro.configs import get_config
    from repro.core.cluster import EPDCluster
    from repro.models.model import init_params
    from repro.serving.request import Request

    rows = ["continuous_batching,value,derived"]
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    page, max_len, chunk, prompt_len, new = 16, 512, 16, 480, 16
    snap = {"config": {"model": "smollm-135m.reduced", "page_size": page,
                       "max_len": max_len, "prefill_chunk": chunk,
                       "prompt_tokens": prompt_len,
                       "max_new_tokens": new}, "workloads": {}}

    def make_requests(n: int) -> List[Request]:
        # distinct long prompts (no prefix sharing): prefill work is real
        return [Request(
            prompt_tokens=[(13 * i + j) % 400 + 2 for j in range(prompt_len)],
            max_new_tokens=new, eos_token=-1) for i in range(n)]

    def build() -> EPDCluster:
        return EPDCluster(cfg, params, max_batch=8, max_len=max_len,
                          paged=True, page_size=page, chunked_prefill=True,
                          prefill_chunk=chunk,
                          n_prefill_pool_pages=1 + 8 * (max_len // page))

    for n in (4, 8):
        serial = build()
        serial.enable_timeline()
        for r in make_requests(n):
            serial.submit(r)
        done_serial = serial.run_until_done()
        t_serial = serial.timeline.makespan

        cont = build()
        t0 = time.perf_counter()
        done_cont = cont.run_continuous(make_requests(n))
        wall = time.perf_counter() - t0
        tl = cont.continuous_timeline
        t_cont = tl.makespan

        # hard gate: iteration-level scheduling must not change a single
        # greedy token, and every page goes back to the pool
        by_id = lambda rs: sorted(rs, key=lambda r: r.request_id)  # noqa: E731
        for a, b in zip(by_id(done_serial), by_id(done_cont)):
            assert list(a.output_tokens) == list(b.output_tokens), \
                "continuous batching changed greedy output"
        leaked = 0
        for eng in [cont.prefill_engine] + cont.decode_engines:
            eng.assert_no_page_leaks()
            leaked += eng.pool.n_used
        assert leaked == 0, f"{leaked} pages still held after drain"

        speedup = t_serial / t_cont
        if n >= 4:
            assert speedup >= MIN_SPEEDUP, \
                f"modeled speedup {speedup:.2f}x < {MIN_SPEEDUP}x at n={n}"
        snap["workloads"][str(n)] = {
            "n_requests": n,
            "serial_makespan_ms": round(t_serial * 1e3, 3),
            "continuous_makespan_ms": round(t_cont * 1e3, 3),
            "speedup": round(speedup, 2),
            "prefill_stream_ms": round(tl.t_prefill * 1e3, 3),
            "decode_stream_ms": round(tl.t_decode * 1e3, 3),
            "scheduler_steps": cont.continuous_scheduler.steps,
            "admission_denials": cont.report.admission_denials,
            "stalls": dict(cont.continuous_scheduler.stall_counts),
            "leaked_pages": leaked,
            "wall_s": round(wall, 2),
        }
        rows.append(f"speedup_n{n},{speedup:.2f}x,"
                    f"serial_{t_serial * 1e3:.1f}ms_vs_"
                    f"continuous_{t_cont * 1e3:.1f}ms")
        rows.append(f"leaked_pages_n{n},{leaked},pool_clean_after_drain")
        if n == 8:
            snap["telemetry"] = cont.metrics.snapshot()

    # ---- adaptive chunk sizing A/B: fixed vs adaptive budget ----
    # a decode-starved shape (2 slots, short prompts, long decodes):
    # prefills finish fast and back up behind busy decode slots, so the
    # adaptive budget shrinks, then grows back once the backlog clears —
    # greedy outputs must not move by a single token
    def build_small() -> EPDCluster:
        return EPDCluster(cfg, params, max_batch=2, max_len=max_len,
                          paged=True, page_size=page, chunked_prefill=True,
                          prefill_chunk=chunk, prefix_cache=True)

    def ab_requests() -> List[Request]:
        return [Request(
            prompt_tokens=[(11 * i + j) % 400 + 2 for j in range(48)],
            max_new_tokens=24, eos_token=-1) for i in range(8)]

    ab_reqs = ab_requests()
    fixed = build_small()
    fixed.run_continuous(ab_requests(), chunk_budget_tokens=3 * chunk)
    t_fixed = fixed.continuous_timeline.makespan

    adapt = build_small()
    adapt.run_continuous(ab_reqs, chunk_budget_tokens=3 * chunk,
                         adaptive_chunking=True)
    t_adapt = adapt.continuous_timeline.makespan
    sched = adapt.continuous_scheduler
    assert sched.budget_shrinks > 0, \
        "decode-starved workload must shrink the adaptive budget"
    for a, b in zip(by_id(fixed.report.completed),
                    by_id(adapt.report.completed)):
        assert list(a.output_tokens) == list(b.output_tokens), \
            "adaptive chunk sizing changed greedy output"
    for eng in [adapt.prefill_engine] + adapt.decode_engines:
        eng.assert_no_page_leaks()
    snap["adaptive_ab"] = {
        "n_requests": len(ab_reqs), "chunk_budget_tokens": 3 * chunk,
        "fixed_makespan_ms": round(t_fixed * 1e3, 3),
        "adaptive_makespan_ms": round(t_adapt * 1e3, 3),
        "budget_shrinks": sched.budget_shrinks,
        "budget_grows": sched.budget_grows,
        "bit_identical": True,
    }
    rows.append(f"adaptive_ab,bit_identical,"
                f"{sched.budget_shrinks}_shrinks_{sched.budget_grows}_grows_"
                f"fixed_{t_fixed * 1e3:.1f}ms_vs_"
                f"adaptive_{t_adapt * 1e3:.1f}ms")

    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_continuous_batching.json")
    with open(os.path.abspath(out), "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows


if __name__ == "__main__":
    for row in bench_continuous_batching():
        print(row)

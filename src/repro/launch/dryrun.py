import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape) combination on
the production meshes with ShapeDtypeStruct inputs (no allocation).

Per case, records:
  * memory_analysis (per-device bytes: args / outputs / temps / peak),
  * cost_analysis (FLOPs, bytes accessed),
  * collective operand bytes by kind (parsed from the partitioned HLO),
  * roofline terms (EXPERIMENTS.md §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
Results land in experiments/dryrun/*.json (one file per case).
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, SHAPES, get_config
from repro.launch.analytic import step_flops, step_hbm_bytes
from repro.launch.hlo import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import TRAIN_MICROBATCHES, build_case, decode_supported
from repro.models.partitioning import tp_rules

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# TPU v5e roofline constants (per chip)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def _shard_tree(mesh, spec_tree, arg_tree):
    """NamedShardings from PartitionSpecs, dropping any dim sharding whose
    mesh-axis product does not divide the dim (jit in_shardings require
    exact divisibility — e.g. vocab 50280 or kv_heads 3 over 16 shards)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def sanitize(spec: P, shape):
        entries = list(spec) + [None] * (len(shape) - len(spec))
        out = []
        for dim, entry in zip(shape, entries):
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= sizes[a]
            out.append(entry if dim % total == 0 else None)
        return P(*out)

    def mk(spec, arg):
        if arg is None:
            return None
        spec = sanitize(spec if spec is not None else P(), arg.shape)
        return NamedSharding(mesh, spec)

    return jax.tree.map(mk, spec_tree, arg_tree,
                        is_leaf=lambda x: x is None or isinstance(x, P))


def run_case(arch: str, shape_name: str, *, multi_pod: bool = False,
             rules_kw: dict = None, save: bool = True,
             label: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = decode_supported(cfg, shape)
    if skip:
        res = {"arch": arch, "shape": shape_name, "skipped": skip}
        if save:
            _save(res, arch, shape_name, multi_pod, label)
        return res

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    rules_kw = dict(rules_kw or {})
    kv_dtype = None
    if rules_kw.pop("kv_fp8", False):
        import jax.numpy as jnp
        kv_dtype = jnp.float8_e4m3fn
    rules = tp_rules(multi_pod=multi_pod, axis_sizes=axis_sizes,
                     mesh=mesh if rules_kw.get('expert_parallel') else None,
                     **rules_kw)
    case = build_case(cfg, shape, rules, kv_dtype=kv_dtype)

    in_shardings = tuple(_shard_tree(mesh, s, a)
                         for s, a in zip(case.in_specs, case.args))
    t0 = time.time()
    with mesh:
        jitted = jax.jit(case.fn, in_shardings=in_shardings,
                         donate_argnums=case.donate)
        lowered = jitted.lower(*case.args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    flops_per_dev = float(cost.get("flops", 0.0))
    bytes_per_dev = float(cost.get("bytes accessed", 0.0))
    coll_total = float(sum(coll.values()))

    # roofline terms (seconds).
    # compute/memory: from the analytic estimator (XLA cost_analysis counts
    # each while-loop body ONCE — a 40-layer scan undercounts ~40x; the raw
    # numbers are still recorded below for reference).
    # collective: HLO-parsed, trip-count corrected (launch/hlo.py).
    if shape.kind == "train":
        from repro.launch.specs import train_plan
        n_micro, _ = train_plan(rules, shape)
    else:
        n_micro = 1
    a_flops = step_flops(cfg, shape)
    a_bytes = step_hbm_bytes(cfg, shape, n_chips, n_micro,
                             kv_elem_bytes=1 if kv_dtype is not None else 2)
    t_compute = a_flops / (n_chips * PEAK_FLOPS)
    t_memory = a_bytes / HBM_BW
    t_coll = coll_total / LINK_BW

    model_flops = 6.0 * cfg.active_param_count() * (
        shape.seq_len * shape.global_batch if shape.kind == "train" else 0)
    if shape.kind == "prefill":
        model_flops = 2.0 * cfg.active_param_count() * shape.seq_len * \
            shape.global_batch
    elif shape.kind == "decode":
        model_flops = 2.0 * cfg.active_param_count() * shape.global_batch

    res = {
        "arch": arch,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "n_chips": n_chips,
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes
                           - mem.alias_size_in_bytes),
        },
        "xla_static_flops_per_device": flops_per_dev,
        "xla_static_bytes_per_device": bytes_per_dev,
        "analytic_flops_global": a_flops,
        "analytic_hbm_bytes_per_device": a_bytes,
        "collective_bytes": coll,
        "roofline": {
            "compute_s": t_compute,
            "memory_s": t_memory,
            "collective_s": t_coll,
            "bottleneck": max(
                (("compute", t_compute), ("memory", t_memory),
                 ("collective", t_coll)), key=lambda kv: kv[1])[0],
        },
        "model_flops_global": model_flops,
        "useful_flops_ratio": (model_flops / a_flops if a_flops else 0.0),
        "rules": rules_kw or {},
    }
    if save:
        _save(res, arch, shape_name, multi_pod, label)
    return res


def _save(res: dict, arch: str, shape: str, multi_pod: bool, label: str):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    mesh_tag = "multipod" if multi_pod else "singlepod"
    suffix = f"_{label}" if label else ""
    f = RESULTS_DIR / f"{arch}_{shape}_{mesh_tag}{suffix}.json"
    f.write_text(json.dumps(res, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--expert-parallel", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--kv-fp8", action="store_true")
    ap.add_argument("--decode-kv", default="heads", choices=["heads", "seq"])
    ap.add_argument("--label", default="")
    args = ap.parse_args()

    rules_kw = {}
    if args.expert_parallel:
        rules_kw["expert_parallel"] = True
    if args.fsdp:
        rules_kw["fsdp"] = True
    if args.kv_fp8:
        rules_kw["kv_fp8"] = True
    if args.decode_kv != "heads":
        rules_kw["decode_kv"] = args.decode_kv

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    res = run_case(arch, shape, multi_pod=mp,
                                   rules_kw=rules_kw, label=args.label)
                    if "skipped" in res:
                        print(f"SKIP {tag}: {res['skipped']}")
                        continue
                    r = res["roofline"]
                    print(f"OK   {tag}: compile={res['compile_s']}s "
                          f"peak={res['memory']['peak_bytes']/2**30:.2f}GiB/dev "
                          f"compute={r['compute_s']*1e3:.2f}ms "
                          f"memory={r['memory_s']*1e3:.2f}ms "
                          f"coll={r['collective_s']*1e3:.2f}ms "
                          f"bound={r['bottleneck']}")
                except Exception as e:
                    failures.append(tag)
                    print(f"FAIL {tag}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} failures: {failures}")


if __name__ == "__main__":
    main()

"""Deployment topologies (paper §4.1 notation).

``-``  separates stages on distinct hardware; ``()`` co-locates stages on
one device with logical isolation; stages written together (``EP``) share
one engine serially (coupled, vLLM-style). ``TP1``/``TP2`` are the
monolithic baselines.

``parse(name)`` builds the spec for one replica; ``scale(spec, k)``
replicates it.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field, replace
from typing import List, Tuple


@dataclass(frozen=True)
class InstanceSpec:
    name: str
    stages: Tuple[str, ...]         # subset of ("E","P","D")
    chips: int = 1
    tp: int = 1
    coloc_group: int = -1           # >=0: shares physical chips with peers
    monolithic: bool = False        # stages share ONE serial execution queue

    def serves(self, stage: str) -> bool:
        return stage in self.stages


@dataclass(frozen=True)
class Deployment:
    name: str
    instances: Tuple[InstanceSpec, ...]
    n_chips: int

    def stage_instances(self, stage: str) -> List[InstanceSpec]:
        return [i for i in self.instances if i.serves(stage)]


def parse(name: str) -> Deployment:
    """Parse deployment notation: 'E-P-D', 'EP-D', '(E-P)-D', '(E-PD)',
    '(E-D)-P', 'E-PD', 'TP1', 'TP2'."""
    if name.upper().startswith("TP"):
        tp = int(name[2:])
        inst = InstanceSpec(f"mono_tp{tp}", ("E", "P", "D"), chips=tp, tp=tp,
                            monolithic=True)
        return Deployment(name, (inst,), tp)

    instances: List[InstanceSpec] = []
    chips = 0
    coloc = 0
    # split on '-' that are not inside parentheses
    units = re.findall(r"\([^)]*\)|[^-()]+", name)
    for unit in units:
        unit = unit.strip()
        if not unit:
            continue
        if unit.startswith("("):
            # co-located: each '-'-separated member is its own logically
            # isolated instance, all sharing ONE chip
            members = [m for m in unit[1:-1].split("-") if m]
            for m in members:
                instances.append(InstanceSpec(
                    f"{m.lower()}{len(instances)}", tuple(m), chips=1,
                    coloc_group=coloc, monolithic=len(m) > 1))
            coloc += 1
            chips += 1
        else:
            # dedicated device; multi-letter unit = coupled serial stages
            instances.append(InstanceSpec(
                f"{unit.lower()}{len(instances)}", tuple(unit), chips=1,
                monolithic=len(unit) > 1))
            chips += 1
    return Deployment(name, tuple(instances), chips)


def scale(dep: Deployment, k: int) -> Deployment:
    """k replicas of a deployment (e.g. '(E-PD)x2')."""
    if k <= 1:
        return dep
    out: List[InstanceSpec] = []
    groups = max([i.coloc_group for i in dep.instances], default=-1) + 1
    for r in range(k):
        for inst in dep.instances:
            cg = inst.coloc_group + r * groups if inst.coloc_group >= 0 else -1
            out.append(replace(inst, name=f"{inst.name}_r{r}",
                               coloc_group=cg))
    return Deployment(f"{dep.name}x{k}", tuple(out), dep.n_chips * k)


# the deployments evaluated in the paper
PAPER_DEPLOYMENTS = ("TP1", "TP2", "E-PD", "(E-PD)", "EP-D", "(E-P)-D",
                     "(E-D)-P", "E-P-D")

"""Radix-tree prefix cache over the paged KV pool (SGLang-style).

Shared prompt prefixes (system prompts, few-shot templates, multi-turn
history) dominate prefill cost in high-concurrency chat workloads. With
the paged KV layout, a shared prefix is nothing but a ref-count on the
physical pages that already hold its KV — this module is the host-side
index that finds them:

* :class:`RadixNode` — one edge of the tree: a page-aligned token run
  (length a multiple of ``page_size``) mapping to the physical pages
  holding that run's KV. Children are keyed by their first page's token
  tuple, so the radix property (at most one child continues a match)
  holds at page granularity and node splits always land on page
  boundaries.
* :class:`PrefixCache` — match / insert / evict over the tree:

  - ``match_and_ref`` returns the longest cached prefix of a prompt:
    whole shared pages are ref-counted for the caller (the request holds
    them for its lifetime), and when the match ends *inside* a page the
    partially-matched page is returned as a copy-on-write source — the
    engine device-copies it into a private page and recomputes only from
    the divergence point, never writing a shared page.
  - ``insert`` retains a finished prefill's full pages in the tree (one
    ref per retained page), splitting existing edges at page boundaries.
  - ``evict`` walks LRU leaves under pool pressure and drops retentions
    whose pages the tree is the last holder of; pages still held by live
    requests are never freed (their nodes are skipped — evicting them
    reclaims nothing).

The tree also runs pool-less (``pool=None``): pure token-prefix
matching with no page bookkeeping, which is what the simulator and the
cache-aware router use to model per-instance prefix locality.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.kv_pool import PagePool


class RadixNode:
    __slots__ = ("tokens", "pages", "children", "parent", "last_access")

    def __init__(self, tokens: Tuple[int, ...],
                 pages: Optional[np.ndarray],
                 parent: Optional["RadixNode"]):
        self.tokens = tokens                  # page-aligned run
        self.pages = pages                    # (len(tokens)//page,) or None
        self.children: Dict[Tuple[int, ...], RadixNode] = {}
        self.parent = parent
        self.last_access = 0

    def __repr__(self) -> str:  # debugging aid
        return (f"RadixNode(run={len(self.tokens)}tok, "
                f"pages={None if self.pages is None else list(self.pages)}, "
                f"kids={len(self.children)})")


@dataclass
class MatchResult:
    """Longest cached prefix of one prompt.

    n_tokens — matched tokens (full pages + any intra-page partial run).
    page_ids — physical ids of the fully-matched pages, ref'd for the
               caller (one ref each; release with pool.unref or hand to
               the slot/payload).
    cow_src  — physical id of the partially-matched page when the match
               ends inside a page (ref'd for the caller, who must copy it
               and then unref), else None.
    """

    n_tokens: int = 0
    page_ids: np.ndarray = field(
        default_factory=lambda: np.zeros((0,), np.int32))
    cow_src: Optional[int] = None

    @property
    def n_full_pages(self) -> int:
        return len(self.page_ids)


@dataclass
class CacheStats:
    lookups: int = 0
    hits: int = 0                  # lookups matching at least one token
    hit_tokens: int = 0
    lookup_tokens: int = 0
    inserted_pages: int = 0
    evicted_pages: int = 0

    @property
    def hit_rate(self) -> float:
        """Token-weighted hit rate: cached tokens / prompt tokens seen."""
        return self.hit_tokens / self.lookup_tokens if self.lookup_tokens \
            else 0.0


class PrefixCache:
    def __init__(self, page_size: int, pool: Optional[PagePool] = None,
                 max_tokens: Optional[int] = None):
        """``pool`` binds retentions to real pages (engine mode; capacity
        is then the pool itself). Pool-less mode (simulator / router
        probes) has no physical backing, so ``max_tokens`` caps the tree
        by LRU leaf eviction instead — without it a long-lived sim grows
        one node per unique prompt tail, unbounded."""
        if pool is not None and pool.page_size != page_size:
            raise ValueError(
                f"page_size {page_size} != pool page {pool.page_size}")
        self.page = int(page_size)
        self.pool = pool
        self.max_tokens = max_tokens
        self.root = RadixNode((), None, None)
        self.stats = CacheStats()
        self._clock = itertools.count(1)
        self._tokens = 0                       # cached tokens, kept in sync

    # -- internal walk -------------------------------------------------------

    def _touch(self, node: RadixNode) -> None:
        t = next(self._clock)
        while node is not None:
            node.last_access = t
            node = node.parent

    def _match_pages(self, tokens: Sequence[int], pos: int, cap: int,
                     run: Tuple[int, ...]) -> int:
        """Pages of ``run`` matched by tokens[pos:cap], given the first
        page already matched (the shared per-node match loop of the walk
        and of insert)."""
        page = self.page
        n = len(run) // page
        j = 1
        while (j < n and pos + (j + 1) * page <= cap and
               tuple(tokens[pos + j * page:pos + (j + 1) * page])
               == run[j * page:(j + 1) * page]):
            j += 1
        return j

    def _walk_full(self, tokens: Sequence[int], cap: int
                   ) -> Tuple[RadixNode, int, int, List[int]]:
        """Follow full-page matches. Returns (node, pages_into_node,
        matched_tokens, matched_page_ids). ``pages_into_node`` > 0 means
        the walk ended mid-node (matched that many of node's pages)."""
        page = self.page
        node = self.root
        pos = 0
        pages: List[int] = []
        while pos + page <= cap:
            child = node.children.get(tuple(tokens[pos:pos + page]))
            if child is None:
                return node, 0, pos, pages
            j = self._match_pages(tokens, pos, cap, child.tokens)
            if child.pages is not None:
                pages.extend(int(p) for p in child.pages[:j])
            pos += j * page
            if j < len(child.tokens) // page:
                return child, j, pos, pages
            node = child
        return node, 0, pos, pages

    def _partial_tail(self, tokens: Sequence[int], cap: int,
                      node: RadixNode, pages_into: int, pos: int
                      ) -> Tuple[int, Optional[int], Optional[RadixNode]]:
        """Longest intra-page match past ``pos`` (< one page of tokens).
        Returns (extra_tokens, cow_page_id_or_None, source_node_or_None —
        the child supplying the partial page when the walk stopped at a
        node boundary, so callers can refresh its LRU stamp)."""
        page = self.page
        limit = min(cap - pos, page)
        if limit <= 0:
            return 0, None, None
        best, best_page, best_node = 0, None, None

        def common(run: Tuple[int, ...], page_id, src) -> None:
            nonlocal best, best_page, best_node
            n = 0
            while n < min(limit, len(run)) and tokens[pos + n] == run[n]:
                n += 1
            if n > best:
                best = n
                best_page = None if page_id is None else int(page_id)
                best_node = src
        if pages_into:                     # diverged mid-node: next page of run
            run = node.tokens[pages_into * page:(pages_into + 1) * page]
            common(run, None if node.pages is None
                   else node.pages[pages_into], None)
        else:                              # node boundary: any child's 1st page
            for key, child in node.children.items():
                common(key, None if child.pages is None else child.pages[0],
                       child)
        return best, best_page, best_node

    # -- public API ----------------------------------------------------------

    def match_len(self, tokens: Sequence[int],
                  cap: Optional[int] = None) -> int:
        """Read-only longest-prefix length in tokens (full pages + partial).
        No refs taken, no stats recorded — the router's probe."""
        cap = len(tokens) if cap is None else min(cap, len(tokens))
        node, into, pos, _ = self._walk_full(tokens, cap)
        extra, _, _ = self._partial_tail(tokens, cap, node, into, pos)
        return pos + extra

    def match_and_ref(self, tokens: Sequence[int],
                      cap: Optional[int] = None) -> MatchResult:
        """Longest cached prefix of ``tokens`` (capped at ``cap`` tokens —
        pass len-1 to force at least one computed token so prefill still
        produces logits). Fully-matched pages and the CoW source page are
        ref'd on behalf of the caller before returning, so no interleaved
        eviction can free them."""
        cap = len(tokens) if cap is None else min(cap, len(tokens))
        node, into, pos, pages = self._walk_full(tokens, cap)
        extra, cow, cow_node = self._partial_tail(tokens, cap, node, into,
                                                  pos)
        self._touch(node)
        if cow_node is not None:           # CoW source child is hot too
            self._touch(cow_node)
        self.stats.lookups += 1
        self.stats.lookup_tokens += len(tokens)
        n = pos + extra
        if n:
            self.stats.hits += 1
            self.stats.hit_tokens += n
        ids = np.asarray(pages, np.int32)
        if self.pool is not None:
            self.pool.ref(ids)
            if cow is not None:
                self.pool.ref([cow])
        return MatchResult(n_tokens=n, page_ids=ids, cow_src=cow)

    def insert(self, tokens: Sequence[int],
               page_ids: Optional[Sequence[int]] = None) -> int:
        """Retain a prefilled prompt's full pages in the tree. ``page_ids``
        must cover ceil(len(tokens)/page) pages (the request's block-table
        row); only the full-page prefix is cached. Newly retained pages
        get one tree ref. Returns the number of pages newly retained."""
        page = self.page
        n_full = len(tokens) // page
        if n_full == 0:
            return 0
        tokens = tuple(int(t) for t in tokens[:n_full * page])
        if self.pool is not None:
            if page_ids is None or len(page_ids) < n_full:
                raise ValueError(
                    f"need >= {n_full} pages for {len(tokens)} tokens")
        node = self.root
        pos = 0
        retained = 0
        while pos < len(tokens):
            key = tuple(tokens[pos:pos + page])
            child = node.children.get(key)
            if child is None:
                run = tokens[pos:]
                pg = None
                if self.pool is not None:
                    pg = np.asarray(
                        [int(p) for p in
                         page_ids[pos // page:n_full]], np.int32)
                    self.pool.ref(pg)
                    retained += len(pg)
                new = RadixNode(run, pg, node)
                node.children[key] = new
                self._tokens += len(run)
                self._touch(new)
                break
            j = self._match_pages(tokens, pos, len(tokens), child.tokens)
            if j < len(child.tokens) // page:
                # split child at the page boundary j
                upper = RadixNode(child.tokens[:j * page],
                                  None if child.pages is None
                                  else child.pages[:j], node)
                child.tokens = child.tokens[j * page:]
                if child.pages is not None:
                    child.pages = child.pages[j:]
                child.parent = upper
                upper.children[tuple(child.tokens[:page])] = child
                upper.last_access = child.last_access
                node.children[key] = upper
                node = upper
            else:
                node = child
            pos += j * page
            if pos >= len(tokens):
                self._touch(node)
        self.stats.inserted_pages += retained
        if self.pool is None and self.max_tokens is not None:
            while self._tokens > self.max_tokens:
                if not self._evict_lru_leaf():
                    break
        return retained

    # -- eviction ------------------------------------------------------------

    def _leaves(self) -> List[RadixNode]:
        out: List[RadixNode] = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            kids = list(n.children.values())
            if not kids and n is not self.root:
                out.append(n)
            stack.extend(kids)
        return out

    def _drop_leaf(self, leaf: RadixNode) -> None:
        del leaf.parent.children[tuple(leaf.tokens[:self.page])]
        leaf.parent = None
        self._tokens -= len(leaf.tokens)

    def _evict_lru_leaf(self) -> int:
        """Pool-less capacity eviction: drop the LRU leaf outright (no
        page bookkeeping to respect). Returns tokens dropped (0 = empty
        tree)."""
        leaves = self._leaves()
        if not leaves:
            return 0
        leaf = min(leaves, key=lambda n: n.last_access)
        n = len(leaf.tokens)
        self._drop_leaf(leaf)
        return n

    def evict(self, n_pages: int) -> int:
        """Drop LRU leaf retentions until >= ``n_pages`` physical pages
        returned to the free list (or nothing evictable remains). Leaves
        whose pages are all still held by live requests are skipped —
        evicting them reclaims no memory. Returns pages actually freed
        (== stats.evicted_pages growth; in-use pages merely lose their
        tree retention and are not counted as reclaimed)."""
        if self.pool is None:
            return 0
        freed = 0
        # One DFS; afterwards only an evicted leaf's parent can become a
        # new leaf, and refcounts only change through our own unrefs (a
        # retained page belongs to exactly one node), so gains computed
        # at pop time stay valid.
        heap = [(leaf.last_access, i, leaf)
                for i, leaf in enumerate(self._leaves())]
        heapq.heapify(heap)
        seq = len(heap)
        while freed < n_pages and heap:
            _, _, leaf = heapq.heappop(heap)
            g = sum(1 for p in leaf.pages if self.pool.refcount(p) == 1)
            if g == 0:
                continue                   # fully in use: reclaims nothing
            self.pool.unref(leaf.pages)
            freed += g
            self.stats.evicted_pages += g
            parent = leaf.parent
            self._drop_leaf(leaf)
            if parent is not self.root and not parent.children:
                heap_entry = (parent.last_access, seq, parent)
                heapq.heappush(heap, heap_entry)
                seq += 1
        return freed

    # -- introspection --------------------------------------------------------

    def retained_pages(self) -> List[int]:
        """All physical pages currently retained by the tree (leak audit)."""
        out: List[int] = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n.pages is not None:
                out.extend(int(p) for p in n.pages)
            stack.extend(n.children.values())
        return out

    @property
    def n_cached_tokens(self) -> int:
        return self._tokens

"""Pure-jnp oracle for the SSD scan kernel.

Delegates to the model's chunked SSD implementation — and additionally
provides a *sequential* (non-chunked) recurrence, so the chunked algorithm
itself is validated against the exact recurrence in tests.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def ssd_ref(x, dt, a, b, c, d_skip, chunk: int,
            init_state: Optional[jax.Array] = None):
    return ssd_chunked(x, dt, a, b, c, d_skip, chunk, init_state)


def ssd_sequential(x, dt, a, b, c, d_skip,
                   init_state: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Exact token-by-token recurrence (slow; ground truth).

    Shapes as in :func:`repro.models.ssm.ssd_chunked`.
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    f32 = jnp.float32
    s0 = (jnp.zeros((B, H, P, N), f32) if init_state is None
          else init_state.astype(f32))

    def step(state, inp):
        xt, dtt, bt, ct = inp                        # (B,H,P),(B,H),(B,N),(B,N)
        dA = jnp.exp(dtt.astype(f32) * a)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dtt.astype(f32), bt.astype(f32),
                         xt.astype(f32))
        state = state * dA[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", state, ct.astype(f32))
        return state, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    final, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)                       # (B,S,H,P)
    y = y + x.astype(f32) * d_skip[None, None, :, None]
    return y.astype(x.dtype), final

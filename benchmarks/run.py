# One function per paper table/figure (benchmarks.paper_tables) plus
# kernel/engine microbenchmarks. Prints CSV rows: name,...,derived.
from __future__ import annotations

import sys
import time


def main() -> None:
    t_start = time.time()
    from benchmarks.extensions import EXTENSION_BENCHMARKS
    from benchmarks.kernel_bench import (bench_engine, bench_kernels,
                                         bench_paged_kv)
    from benchmarks.paper_tables import ALL_BENCHMARKS

    argv = list(sys.argv[1:])
    trace_path = None
    if "--trace" in argv:
        i = argv.index("--trace")
        if i + 1 >= len(argv):
            raise SystemExit("usage: run.py [only] [--trace out.json]")
        trace_path = argv[i + 1]
        del argv[i:i + 2]
    only = argv[0] if argv else None
    for fn in ALL_BENCHMARKS + EXTENSION_BENCHMARKS:
        if only and only not in fn.__name__:
            continue
        t0 = time.time()
        for row in fn():
            print(row)
        print(f"# {fn.__name__} done in {time.time() - t0:.1f}s", flush=True)
    if only is None or "kernel" in only or "engine" in only:
        for row in bench_kernels():
            print(row)
        for row in bench_engine():
            print(row)
    if only is None or "paged" in only:
        for row in bench_paged_kv():
            print(row)
    if only is None or "prefix" in only:
        from benchmarks.prefix_bench import bench_prefix_cache
        for row in bench_prefix_cache():
            print(row)
    if only is None or "chunked" in only:
        from benchmarks.chunked_prefill_bench import bench_chunked_prefill
        for row in bench_chunked_prefill():
            print(row)
    if only is None or "batching" in only:
        from benchmarks.continuous_batching_bench import \
            bench_continuous_batching
        for row in bench_continuous_batching():
            print(row)
    if only is None or "preempt" in only:
        from benchmarks.preemption_bench import bench_preemption
        for row in bench_preemption():
            print(row)
    if only is None or "fault" in only:
        from benchmarks.fault_bench import bench_faults
        for row in bench_faults():
            print(row)
    if only is None or "encode" in only:
        from benchmarks.encode_bench import bench_encode
        for row in bench_encode():
            print(row)
    # --trace forces the traced observability workload so there is
    # always a Perfetto trace to export, whatever the filter says
    if only is None or "observ" in only or trace_path:
        from benchmarks.observability_bench import bench_observability
        for row in bench_observability(trace_path=trace_path):
            print(row)
    print(f"# total {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()

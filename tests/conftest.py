import os

import jax
import pytest

# Tests run on the single CPU device (the dry-run sets its own
# XLA_FLAGS in-process; see src/repro/launch/dryrun.py).
jax.config.update("jax_platform_name", "cpu")

# Hypothesis profiles: CI runs with HYPOTHESIS_PROFILE=ci — deadlines
# stay off (jit compilation makes first examples arbitrarily slow) and
# the property suites scale their example budgets down via
# ``hyp_max_examples``. Local runs keep the full budgets.
HYPOTHESIS_PROFILE = os.environ.get("HYPOTHESIS_PROFILE", "dev")

try:
    from hypothesis import settings

    settings.register_profile("ci", deadline=None, print_blob=True)
    settings.register_profile("dev", deadline=None)
    settings.load_profile(HYPOTHESIS_PROFILE
                          if HYPOTHESIS_PROFILE in ("ci", "dev")
                          else "dev")
except ImportError:                       # hypothesis is optional (tier-1
    pass                                  # suites importorskip it)


def hyp_max_examples(n: int) -> int:
    """Per-test example budget honoring the CI profile: a quarter of the
    local budget (floor 5) keeps the smoke jobs inside their timeout
    while the nightly/dev runs explore the full space."""
    return max(5, n // 4) if HYPOTHESIS_PROFILE == "ci" else n


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)

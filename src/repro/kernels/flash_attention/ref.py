"""Pure-jnp oracle for the flash-attention kernel (GQA, causal/window)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _attention_dense(q, k, v, q_pos, kv_pos, window, causal):
    b, s, nq, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    qg = q.reshape(b, s, nkv, g, hd)
    scores = jnp.einsum("bskgh,bTkh->bkgsT", qg, k,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    valid = (kv_pos[:, None, :] >= 0) & (q_pos[:, :, None] >= 0)
    if causal:
        valid &= kv_pos[:, None, :] <= q_pos[:, :, None]
        if window is not None:
            valid &= kv_pos[:, None, :] > q_pos[:, :, None] - window
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (padding queries) produce uniform probs; zero them
    any_valid = jnp.any(valid, axis=-1)[:, None, None, :, None]
    probs = jnp.where(any_valid, probs, 0.0)
    out = jnp.einsum("bkgsT,bTkh->bskgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, nq, hd).astype(q.dtype)


# above this many query positions, scan over query chunks so the (s, S)
# score tensor never materializes in full (the jnp analogue of the flash
# kernel's tiling; keeps long-seq dry-runs within per-chip HBM). Each
# chunk is remat'ed: the backward pass recomputes one chunk's scores at a
# time instead of keeping every chunk's softmax residuals alive.
_CHUNK_THRESHOLD = 2048
_Q_CHUNK = 1024


def attention_ref(q, k, v, q_pos, kv_pos, *, window: Optional[int] = None,
                  causal: bool = True) -> jax.Array:
    """q: (b, s, nq, hd); k, v: (b, S, nkv, hd); q_pos: (b, s); kv_pos: (b, S).

    Positions < 0 mark padding / empty cache slots. GQA: nq = g * nkv.
    Returns (b, s, nq, hd) in q.dtype.
    """
    b, s, nq, hd = q.shape
    if s <= _CHUNK_THRESHOLD:
        return _attention_dense(q, k, v, q_pos, kv_pos, window, causal)

    c = _Q_CHUNK
    pad = (-s) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    nc = q.shape[1] // c
    qc = jnp.moveaxis(q.reshape(b, nc, c, nq, hd), 1, 0)
    pc = jnp.moveaxis(q_pos.reshape(b, nc, c), 1, 0)

    @jax.checkpoint
    def one(args):
        qi, pi = args
        return _attention_dense(qi, k, v, pi, kv_pos, window, causal)

    out = jax.lax.map(one, (qc, pc))                 # (nc, b, c, nq, hd)
    out = jnp.moveaxis(out, 0, 1).reshape(b, nc * c, nq, hd)
    return out[:, :s]

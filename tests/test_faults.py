"""Failure-domain chaos layer: deterministic injection, typed errors,
retry/backoff, and the four recovery arms (store refetch->recompute,
transfer retry->replan, swap-loss suffix recompute, decode-crash
cross-instance re-route) — each proven bit-identical to its fault-free
run where the tentpole demands it."""
import jax
import pytest

from repro.configs import get_config
from repro.core import kv_transfer as kt
from repro.core.cluster import EPDCluster
from repro.core.faults import (DEFAULT_RETRY, NO_RETRY, SITE_STORE_FETCH,
                               SITE_SWAP_IN, SITE_TRANSFER_WIRE, SITES,
                               ArmedFault, FaultError, FaultInjector,
                               FaultPlan, InstanceDown, NoFreeSlot,
                               PlanError, RetryPolicy, StoreMiss, SwapLost,
                               TransferError, _unit)
from repro.core.mm_store import MMStore
from repro.models.model import init_params
from repro.serving.engine import Engine
from repro.serving.kv_pool import PagePool
from repro.serving.request import Request


@pytest.fixture(scope="module")
def smollm():
    cfg = get_config("smollm-135m").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def llava():
    cfg = get_config("llava-next-mistral-7b").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# deterministic fault plane
# ---------------------------------------------------------------------------

def test_unit_draw_is_pure_and_seeded():
    a = _unit(3, "transfer.wire", ("r", 1), 0)
    assert a == _unit(3, "transfer.wire", ("r", 1), 0)
    assert 0.0 <= a < 1.0
    # any coordinate change moves the draw
    assert a != _unit(4, "transfer.wire", ("r", 1), 0)
    assert a != _unit(3, "transfer.handshake", ("r", 1), 0)
    assert a != _unit(3, "transfer.wire", ("r", 2), 0)
    assert a != _unit(3, "transfer.wire", ("r", 1), 1)


def test_injector_replay_is_deterministic():
    plan = FaultPlan(seed=11, rates={s: 0.3 for s in SITES})
    calls = [(s, k, a) for s in sorted(SITES)
             for k in ("x", ("g", 2), None) for a in (0, 1)]
    r1 = [FaultInjector(plan).should_fail(s, k, a) for s, k, a in calls]
    inj = FaultInjector(plan)
    r2 = [inj.should_fail(s, k, a) for s, k, a in calls]
    assert r1 == r2
    assert any(r1) and not all(r1)
    assert inj.stats.checks and inj.n_fired() == sum(r1)


def test_injector_rate_independent_of_call_order():
    """The same (site, key, attempt) coordinate gives the same answer no
    matter what was checked before it — decisions are a pure function of
    the plan, never of interleaving."""
    plan = FaultPlan(seed=5, rates={SITE_TRANSFER_WIRE: 0.5})
    a = FaultInjector(plan)
    _ = [a.should_fail(SITE_TRANSFER_WIRE, key=i) for i in range(20)]
    target = a.should_fail(SITE_TRANSFER_WIRE, key="probe")
    b = FaultInjector(plan)
    assert b.should_fail(SITE_TRANSFER_WIRE, key="probe") == target


def test_armed_faults_fire_first_and_decrement():
    inj = FaultInjector(FaultPlan(armed=[
        ArmedFault(SITE_STORE_FETCH, key="k", count=2)]))
    assert inj.armed_remaining == 2
    assert not inj.should_fail(SITE_STORE_FETCH, key="other")
    assert inj.should_fail(SITE_STORE_FETCH, key="k")
    assert inj.should_fail(SITE_STORE_FETCH, key="k")
    assert not inj.should_fail(SITE_STORE_FETCH, key="k")
    assert inj.armed_remaining == 0
    # key=None arms match any key
    inj.arm(SITE_SWAP_IN)
    assert inj.should_fail(SITE_SWAP_IN, key=123)


def test_rate_cap_bounds_probabilistic_fires():
    plan = FaultPlan(seed=0, rates={SITE_TRANSFER_WIRE: 1.0},
                     max_faults={SITE_TRANSFER_WIRE: 3})
    inj = FaultInjector(plan)
    fired = sum(inj.should_fail(SITE_TRANSFER_WIRE, key=i)
                for i in range(10))
    assert fired == 3


def test_plan_and_policy_validation():
    with pytest.raises(PlanError, match="unknown fault site"):
        FaultPlan(rates={"nope": 0.5}).validate()
    with pytest.raises(PlanError, match="rate"):
        FaultPlan(rates={SITE_SWAP_IN: 1.5}).validate()
    with pytest.raises(PlanError, match="count"):
        FaultPlan(armed=[ArmedFault(SITE_SWAP_IN, count=0)]).validate()
    with pytest.raises(PlanError):
        FaultInjector().should_fail("not.a.site")
    with pytest.raises(PlanError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(PlanError, match="jitter"):
        RetryPolicy(jitter=2.0)
    with pytest.raises(PlanError, match="backoff_mult"):
        RetryPolicy(backoff_mult=0.5)
    # PlanError is catchable as both branches of its legacy ancestry
    assert issubclass(PlanError, ValueError)
    assert issubclass(PlanError, RuntimeError)
    assert issubclass(PlanError, FaultError)


def test_retry_policy_backoff_capped_and_seeded():
    p = RetryPolicy(max_attempts=6, backoff_base=1e-3, backoff_mult=2.0,
                    backoff_cap=4e-3, jitter=0.1, seed=9)
    delays = [p.backoff(a, key="op") for a in range(1, 6)]
    assert delays == [p.backoff(a, key="op") for a in range(1, 6)]  # replay
    for a, d in enumerate(delays, start=1):
        raw = min(4e-3, 1e-3 * 2.0 ** (a - 1))
        assert raw * 0.9 <= d <= raw * 1.1
    assert sum(delays) <= p.worst_case_retry_time() + 1e-12
    assert RetryPolicy(jitter=0.0).backoff(1) == 2e-3
    assert NO_RETRY.max_attempts == 1 and NO_RETRY.worst_case_retry_time() == 0


# ---------------------------------------------------------------------------
# kv_transfer: plan input validation (typed) + recovery
# ---------------------------------------------------------------------------

def test_plan_input_validation():
    ok = dict(n_layers=4, bytes_per_layer=1e6, per_layer_compute=1e-3,
              handshake=1e-3, link_bw=1e9)
    kt.plan("grouped", **ok)                       # sanity: valid baseline
    for bad in (dict(n_layers=0), dict(bytes_per_layer=0.0),
                dict(bytes_per_layer=-1.0), dict(per_layer_compute=-1e-3),
                dict(handshake=-1e-3), dict(link_bw=0.0),
                dict(group_size=-1), dict(page_bytes=-1.0)):
        with pytest.raises(PlanError):
            kt.plan("grouped", **{**ok, **bad})


def test_plan_chunked_input_validation():
    ok = dict(chunk_bytes=[1e6, 2e6], chunk_compute=[1e-3, 2e-3],
              handshake=1e-3, link_bw=1e9)
    kt.plan_chunked(**ok)
    with pytest.raises(PlanError):
        kt.plan_chunked(**{**ok, "chunk_bytes": []})
    with pytest.raises(PlanError):
        kt.plan_chunked(**{**ok, "chunk_bytes": [1e6, -1.0]})
    with pytest.raises(PlanError):
        kt.plan_chunked(**{**ok, "chunk_compute": [1e-3, -1.0]})
    with pytest.raises(PlanError):
        kt.plan_chunked(**{**ok, "link_bw": 0.0})
    # legacy compat: length mismatch stays a ValueError matching "segments"
    with pytest.raises(ValueError, match="segments"):
        kt.plan_chunked(**{**ok, "chunk_compute": [1e-3]})


def _plan():
    return kt.plan("grouped", n_layers=8, bytes_per_layer=1e6,
                   per_layer_compute=1e-3, handshake=1e-3, link_bw=1e9,
                   group_size=2)


def test_recover_plan_zero_fault_is_identity():
    p = _plan()
    out, rec = kt.recover_plan(p, injector=FaultInjector(),
                               policy=DEFAULT_RETRY, handshake=1e-3,
                               link_bw=1e9)
    assert out is p and rec.faults == 0 and rec.retry_time == 0.0


def test_recover_plan_transient_fault_heals_with_charged_retry():
    p = _plan()
    inj = FaultInjector(FaultPlan(armed=[ArmedFault(SITE_TRANSFER_WIRE)]))
    out, rec = kt.recover_plan(p, injector=inj, policy=DEFAULT_RETRY,
                               handshake=1e-3, link_bw=1e9, key="req")
    assert rec.wire_faults == 1 and rec.retries == 1
    assert rec.retry_time > 0
    # payload conserved: every group delivered exactly once
    assert sorted(g.start for g in out.groups) == \
        sorted(g.start for g in p.groups)
    assert sum(g.nbytes for g in out.groups) == \
        sum(g.nbytes for g in p.groups)
    # compute timeline untouched; latency/exposure absorb the retry
    assert out.prefill_end == p.prefill_end
    assert out.kv_latency > p.kv_latency
    assert out.exposed_latency >= p.exposed_latency


def test_recover_plan_exhausted_group_takes_fresh_replan():
    p = _plan()
    # enough armed faults to exhaust one group's attempts, then heal
    n = DEFAULT_RETRY.max_attempts
    inj = FaultInjector(FaultPlan(armed=[
        ArmedFault(SITE_TRANSFER_WIRE, count=n)]))
    out, rec = kt.recover_plan(p, injector=inj, policy=DEFAULT_RETRY,
                               handshake=1e-3, link_bw=1e9, key="req")
    assert rec.replanned_groups >= 1
    assert sorted(g.start for g in out.groups) == \
        sorted(g.start for g in p.groups)


def test_recover_plan_recovery_off_raises_typed():
    p = _plan()
    inj = FaultInjector(FaultPlan(armed=[ArmedFault(SITE_TRANSFER_WIRE)]))
    with pytest.raises(TransferError) as ei:
        kt.recover_plan(p, injector=inj, policy=NO_RETRY, handshake=1e-3,
                        link_bw=1e9, replan=False)
    assert ei.value.site == SITE_TRANSFER_WIRE
    assert isinstance(ei.value, RuntimeError)


def test_recover_plan_deadline_escalates_to_replan():
    p = _plan()
    inj = FaultInjector(FaultPlan(armed=[
        ArmedFault(SITE_TRANSFER_WIRE, count=2)]))
    policy = RetryPolicy(max_attempts=5, deadline=1e-9)   # no retry budget
    out, rec = kt.recover_plan(p, injector=inj, policy=policy,
                               handshake=1e-3, link_bw=1e9, key="req")
    assert rec.deadline_hits >= 1 and rec.replanned_groups >= 1
    assert sorted(g.start for g in out.groups) == \
        sorted(g.start for g in p.groups)


# ---------------------------------------------------------------------------
# MM store: injector routing + typed fetch
# ---------------------------------------------------------------------------

def test_store_legacy_inject_fault_shim_is_one_shot():
    s = MMStore()
    s.put("k", "v", 8)
    s.inject_fault("k")
    assert s.get("k") is None                   # the injected loss
    assert s.get("k") == "v"                    # one-shot: healed
    assert s.stats.faults_injected == 1


def test_store_multi_shot_and_rates():
    s = MMStore()
    s.put("k", "v", 8)
    s.injector.arm(SITE_STORE_FETCH, key="k", count=3)
    assert [s.get("k") for _ in range(4)] == [None, None, None, "v"]
    # per-site rates through a shared plan
    s2 = MMStore(injector=FaultInjector(
        FaultPlan(seed=2, rates={SITE_STORE_FETCH: 1.0},
                  max_faults={SITE_STORE_FETCH: 2})))
    s2.put("k", "v", 8)
    assert s2.get("k") is None and s2.get("k") is None
    assert s2.get("k") == "v"


def test_store_typed_fetch_and_retry_heal():
    s = MMStore()
    s.put("k", "v", 8)
    s.inject_fault("k")
    with pytest.raises(StoreMiss) as ei:
        s.fetch("k")
    assert ei.value.key == "k"
    # a retry (attempt=1) re-draws: the armed fault is consumed, heals
    assert s.fetch("k", attempt=1) == "v"
    with pytest.raises(StoreMiss):
        s.fetch("absent")


# ---------------------------------------------------------------------------
# typed errors replacing string raises
# ---------------------------------------------------------------------------

def test_no_free_slot_is_typed_and_legacy_compatible(smollm):
    cfg, params = smollm
    eng = Engine(cfg, params, max_batch=1, max_len=32)
    r1 = Request(prompt_tokens=[3, 4, 5], max_new_tokens=4)
    f, c = eng.prefill_request(r1)
    eng.insert(r1, c, f)
    r2 = Request(prompt_tokens=[6, 7, 8], max_new_tokens=4)
    f2, c2 = eng.prefill_request(r2)
    with pytest.raises(NoFreeSlot):
        eng.insert(r2, c2, f2)
    with pytest.raises(RuntimeError, match="no free decode slot"):
        eng.insert(r2, c2, f2)                  # legacy string-match path


def test_swap_lost_semantics():
    inj = FaultInjector(FaultPlan(armed=[ArmedFault(SITE_SWAP_IN)]))
    pool = PagePool(9, 4, injector=inj)
    ids = pool.alloc(3)
    h = pool.swap_out(ids, data="kv")
    with pytest.raises(SwapLost) as ei:
        pool.swap_in(h)
    assert ei.value.handle_id == h.handle_id
    assert ei.value.n_pages == 3
    # the entry is gone: the handle is consumed, pages stay free, the
    # audit balances with no outstanding handles
    assert pool.n_swapped_pages == 0 and pool.n_free == 8
    assert pool.swap_lost_total == 1
    pool.assert_balanced()
    with pytest.raises(ValueError, match="unknown or already-consumed"):
        pool.swap_in(h)


# ---------------------------------------------------------------------------
# recovery arms on the REAL cluster/engine
# ---------------------------------------------------------------------------

def _text_reqs(n=4, m=8):
    return [Request(prompt_tokens=list(range(3 + i, 20 + i)),
                    max_new_tokens=m) for i in range(n)]


def test_cluster_store_retry_arm_heals_before_recompute(llava):
    cfg, params = llava
    cl = EPDCluster(cfg, params, max_batch=2, max_len=64,
                    faults=FaultPlan(seed=0), retry=DEFAULT_RETRY)
    req = Request(prompt_tokens=[3, 4, 5, 6], max_new_tokens=2,
                  mm_payload=b"x", mm_tokens=4)
    key = cl.encode(req)
    cl.store.inject_fault(key)           # one-shot: first attempt fails
    cl.prefill(req, key)
    assert cl.report.store_retries == 1  # healed on retry
    assert cl.report.recomputes == 0
    assert cl.report.retry_time_total > 0


def test_cluster_store_exhausted_retries_take_recompute_arm(llava):
    cfg, params = llava
    cl = EPDCluster(cfg, params, max_batch=2, max_len=64,
                    faults=FaultPlan(seed=0),
                    retry=RetryPolicy(max_attempts=2))
    req = Request(prompt_tokens=[3, 4, 5, 6], max_new_tokens=2,
                  mm_payload=b"x", mm_tokens=4)
    key = cl.encode(req)
    cl.store.injector.arm(SITE_STORE_FETCH, key=key, count=5)
    cl.prefill(req, key)
    assert cl.report.store_retries == 1           # both attempts failed
    assert cl.report.recomputes == 1              # §3.2 local recompute


def test_cluster_decode_crash_reroute_bit_identical(smollm):
    cfg, params = smollm
    ref = _text_reqs()
    c0 = EPDCluster(cfg, params, max_batch=2, max_len=64, paged=True,
                    page_size=8, prefix_cache=True, n_decode=2)
    for r in ref:
        c0.submit(r)
    c0.run_until_done()

    plan = FaultPlan(seed=1, armed=[ArmedFault("decode.crash",
                                               key=(0, 3))])
    reqs = _text_reqs()
    c1 = EPDCluster(cfg, params, max_batch=2, max_len=64, paged=True,
                    page_size=8, prefix_cache=True, n_decode=2,
                    faults=plan)
    for r in reqs:
        c1.submit(r)
    done = c1.run_until_done()
    assert c1.report.instance_crashes == 1
    assert c1.report.reroutes >= 1
    assert not c1.report.lost and len(done) == len(reqs)
    for a, b in zip(ref, reqs):
        assert a.output_tokens == b.output_tokens
    # the re-prefill rode the prefix cache: its suffix-only compute is
    # visible as cached tokens on the prefill engine
    assert c1.prefill_engine.prefill_tokens_computed < \
        c1.prefill_engine.prefill_tokens_total
    # survivors stay leak-free (the dead instance vanished with its pool)
    for i in c1.live_decode_indices():
        c1.decode_engines[i].assert_no_page_leaks()
    c1.prefill_engine.assert_no_page_leaks()


def test_cluster_decode_crash_recovery_off_loses_requests(smollm):
    cfg, params = smollm
    plan = FaultPlan(seed=1, armed=[ArmedFault("decode.crash",
                                               key=(0, 3))])
    reqs = _text_reqs()
    cl = EPDCluster(cfg, params, max_batch=2, max_len=64, paged=True,
                    page_size=8, prefix_cache=True, n_decode=2,
                    faults=plan, recovery=False)
    for r in reqs:
        cl.submit(r)
    done = cl.run_until_done()
    assert cl.report.instance_crashes == 1
    assert len(cl.report.lost) >= 1
    assert all(r.killed for r in cl.report.lost)
    # accounting closes: every request is either done or surfaced lost
    assert len(done) + len(cl.report.lost) == len(reqs)


def test_crash_twice_is_typed_instance_down(smollm):
    cfg, params = smollm
    cl = EPDCluster(cfg, params, max_batch=2, max_len=64, n_decode=2)
    cl._crash_instance(0)
    with pytest.raises(InstanceDown):
        cl._crash_instance(0)


def test_engine_swap_lost_recompute_bit_identical(smollm):
    cfg, params = smollm

    def serve(eng, preempt_at=()):
        r = Request(prompt_tokens=list(range(3, 20)), max_new_tokens=8)
        f, p = eng.prefill_request(r)
        eng.insert(r, p, f)
        step = 0
        while (any(s is r for s in eng.slots)
               or any(pr.req is r for pr in eng.preempted)):
            if step in preempt_at and any(s is r for s in eng.slots):
                eng.preempt_slot(next(i for i, s in enumerate(eng.slots)
                                      if s is r))
            eng.decode_step()
            step += 1
            assert step < 100
        return r

    e0 = Engine(cfg, params, max_batch=2, max_len=64, paged=True,
                page_size=8, preemption=True)
    ref = serve(e0, preempt_at=(3,))

    inj = FaultInjector(FaultPlan(armed=[ArmedFault(SITE_SWAP_IN)]))
    e1 = Engine(cfg, params, max_batch=2, max_len=64, paged=True,
                page_size=8, preemption=True, faults=inj)
    out = serve(e1, preempt_at=(3,))
    assert out.output_tokens == ref.output_tokens
    assert e1.swap_lost_recomputes == 1
    assert e1.pool.swap_lost_total == 1
    e1.assert_no_page_leaks()
    assert e1.pool.n_used == 0


def test_cluster_swap_loss_surfaces_in_report(smollm):
    """A preemption cluster under an armed swap-in loss still completes
    every request (suffix recompute) and reports the loss count."""
    cfg, params = smollm
    plan = FaultPlan(seed=3, armed=[ArmedFault(SITE_SWAP_IN)])
    reqs = [Request(prompt_tokens=list(range(3 + i, 19 + i)),
                    max_new_tokens=10) for i in range(3)]
    cl = EPDCluster(cfg, params, max_batch=2, max_len=64, paged=True,
                    page_size=4, preemption=True,
                    n_decode_pool_pages=17, faults=plan)
    for r in reqs:
        cl.submit(r)
    done = cl.run_until_done(max_steps=300)
    assert len(done) + len(cl.report.lost) == len(reqs)
    if cl.report.preemptions:
        assert cl.report.swap_losses >= 0   # populated from pools
    for i in cl.live_decode_indices():
        cl.decode_engines[i].assert_no_page_leaks()

"""whisper-base [audio] — enc-dec transformer backbone, conv frontend STUB.

[arXiv:2212.04356] — the mel-spectrogram + conv feature extractor is a
stub that emits 1500 frame embeddings (30 s of audio); the 6-layer
encoder and 6-layer decoder (self + cross attention) are implemented.
"""
from repro.configs.base import (EncoderConfig, FrontendConfig, LayerSpec,
                                ModelConfig)

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    n_layers=6,                   # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    pattern=(LayerSpec("attn", "mlp"),),
    encoder=EncoderConfig(n_layers=6, n_ctx=1500),
    frontend=FrontendConfig(kind="audio", tokens_per_item=1500, feature_dim=512),
    tie_embeddings=True,
    source="arXiv:2212.04356",
)

"""Quickstart: build a model, serve a few requests through the full
EPD-disaggregated pipeline with REAL compute (reduced config, CPU).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.core.cluster import EPDCluster
from repro.models.model import init_params
from repro.models.params import count_params
from repro.serving.request import Request


def main():
    # the paper's primary scenario: a VLM served with EPD disaggregation
    cfg = get_config("llava-next-mistral-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.name}  params: {count_params(params):,}")

    cluster = EPDCluster(cfg, params, max_batch=4, max_len=96,
                         kv_scheme="grouped")

    requests = [
        # two multimodal requests sharing one image (MM Store dedup)
        Request(prompt_tokens=[5, 6, 7, 8, 9], max_new_tokens=8,
                mm_payload=b"cat-photo.jpg", mm_tokens=8),
        Request(prompt_tokens=[10, 11, 12], max_new_tokens=8,
                mm_payload=b"cat-photo.jpg", mm_tokens=8),
        # a text-only request (takes the P-D path, skips Encode)
        Request(prompt_tokens=[20, 21, 22, 23], max_new_tokens=8),
    ]
    for r in requests:
        cluster.submit(r)
    done = cluster.run_until_done()

    for r in done:
        path = "E->P->D" if r.is_multimodal else "P->D"
        print(f"request {r.request_id} [{path}]: {r.output_tokens}")
    print(f"MM store: {cluster.store.stats}")
    print(f"mean P->D KV overlap ratio: {cluster.report.mean_kv_overlap:.3f}")


if __name__ == "__main__":
    main()

"""Config registry + parameter accounting."""
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, SHAPES, get_config

EXPECTED_PARAMS_B = {
    # name -> (total B, tolerance fraction) vs public figures
    "glm4-9b": (9.4, 0.15),
    "llama4-scout-17b-a16e": (109.0, 0.15),
    "jamba-v0.1-52b": (52.0, 0.15),
    "deepseek-7b": (6.9, 0.15),
    "llama3.2-1b": (1.24, 0.15),
    "whisper-base": (0.074, 0.25),
    "mamba2-370m": (0.37, 0.20),
    "llava-next-mistral-7b": (7.25, 0.15),
    "smollm-135m": (0.135, 0.15),
    "mixtral-8x7b": (46.7, 0.15),
}


def test_registry_complete():
    assert len(ASSIGNED_ARCHS) == 10
    assert "openpangu-7b-vl" in ALL_ARCHS           # the paper's own model
    assert len(SHAPES) == 4


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_counts_match_public_figures(arch):
    cfg = get_config(arch)
    total = cfg.param_count() / 1e9
    want, tol = EXPECTED_PARAMS_B[arch]
    assert abs(total - want) / want < tol, f"{arch}: {total:.2f}B vs {want}B"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_exact_geometry(arch):
    cfg = get_config(arch)
    spec = {
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == spec


def test_moe_specs():
    assert get_config("mixtral-8x7b").moe.n_experts == 8
    assert get_config("mixtral-8x7b").moe.top_k == 2
    assert get_config("llama4-scout-17b-a16e").moe.top_k == 1
    assert get_config("jamba-v0.1-52b").moe.n_experts == 16


def test_jamba_interleave():
    cfg = get_config("jamba-v0.1-52b")
    mixers = [s.mixer for s in cfg.pattern]
    assert mixers.count("attn") == 1 and mixers.count("ssm") == 7
    ffns = [s.ffn for s in cfg.pattern]
    assert ffns.count("moe") == 4           # every other layer


def test_sub_quadratic_flags():
    assert get_config("mamba2-370m").sub_quadratic
    assert get_config("jamba-v0.1-52b").sub_quadratic
    assert get_config("mixtral-8x7b").sub_quadratic     # SWA
    assert not get_config("glm4-9b").sub_quadratic
    assert not get_config("whisper-base").sub_quadratic


def test_reduced_configs_are_small():
    for arch in ASSIGNED_ARCHS:
        r = get_config(arch).reduced()
        assert r.d_model <= 512
        assert r.n_layers <= max(2 * len(r.pattern), len(r.pattern))
        if r.moe:
            assert r.moe.n_experts <= 4

"""Public model API: build / init / forward per mode.

Three entry points used by training, serving and the dry-run:

* ``train_forward``   — full-seq causal LM loss path (no caches, remat).
* ``prefill_forward`` — full-seq forward populating caches, last-token logits.
* ``decode_forward``  — one-token step against caches.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.layers import rms_norm
from repro.models.params import (abstract_params, init_params, param_pspecs,
                                 param_structure)
from repro.models.partitioning import shard


def _ce_loss(params, cfg: ModelConfig, h_text, targets):
    """Token-mean cross entropy; returns (sum_nll, n_valid)."""
    logits = T.lm_logits(params, cfg, h_text).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(
        logp, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
    mask = (targets >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask), jnp.sum(mask)


def train_forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                  *, remat: bool = True, loss_chunk: int = 0
                  ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Causal-LM loss. batch: tokens (B,S_t), labels (B,S_t), optional
    mm_embeds (B,n_mm,feat) and enc_frames (B,T,feat).

    loss_chunk > 0: compute the lm-head matmul + cross entropy in sequence
    chunks under lax.scan so the (B, S, vocab) logits tensor never
    materializes — required for FSDP training where the batch is spread
    over all mesh axes and vocab cannot also be sharded.
    """
    tokens = batch["tokens"]
    x, positions = T.embed_inputs(params, cfg, tokens, batch.get("mm_embeds"))
    enc_out = None
    if cfg.encoder is not None:
        enc_out = T.run_encoder(params, cfg, batch["enc_frames"])
    h, _, aux = T.run_decoder(params, cfg, x, positions, caches=None,
                              enc_out=enc_out, remat=remat)
    n_mm = x.shape[1] - tokens.shape[1]
    h_text = h[:, n_mm:]
    # next-token prediction within the text segment
    h_pred = h_text[:, :-1]
    targets = batch["labels"][:, 1:]
    if loss_chunk and h_pred.shape[1] > loss_chunk:
        c = loss_chunk
        pad = (-h_pred.shape[1]) % c
        if pad:
            h_pred = jnp.pad(h_pred, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)),
                              constant_values=-1)
        nc = h_pred.shape[1] // c
        hc = jnp.moveaxis(h_pred.reshape(h_pred.shape[0], nc, c, -1), 1, 0)
        tc = jnp.moveaxis(targets.reshape(targets.shape[0], nc, c), 1, 0)

        def step(carry, inp):
            s, n = carry
            hi, ti = inp
            ds, dn = _ce_loss(params, cfg, hi, ti)
            return (s + ds, n + dn), None

        (sum_nll, n_valid), _ = jax.lax.scan(
            step, (jnp.zeros(()), jnp.zeros(())), (hc, tc))
    else:
        sum_nll, n_valid = _ce_loss(params, cfg, h_pred, targets)
    loss = sum_nll / jnp.clip(n_valid, 1.0)
    aux_w = 0.01
    total = loss + aux_w * aux
    return total, {"loss": loss, "aux": aux}


def prefill_forward(params, cfg: ModelConfig, tokens, caches,
                    *, lengths: Optional[jax.Array] = None,
                    mm_embeds=None, enc_frames=None,
                    prefix_len: Optional[jax.Array] = None,
                    pos_base: Optional[jax.Array] = None,
                    mm_feats=None, mm_start=None):
    """Populate caches from a (padded) prompt batch.

    lengths: (B,) true prompt lengths (including mm tokens). Padded
    positions get position -1 so they are masked everywhere.
    prefix_len / pos_base (paged suffix prefill, batch 1): the first
    ``prefix_len`` tokens are already cached in pool pages; ``tokens``
    holds only the slice from the page-aligned ``pos_base`` onward (the
    leading ``prefix_len - pos_base`` entries are dummies). Queries get
    absolute positions, attend over gathered-prefix + in-batch KV, and
    the returned logits are still for the true last prompt token.
    mm_feats / mm_start (Encode-stage hand-off): features already
    projected to d_model, (B, n_mm, d) — scattered over the embedding
    stream at absolute positions [mm_start, mm_start + n_mm), replacing
    the placeholder token embeddings there. Unlike ``mm_embeds`` (the
    fused prepend path) this composes with suffix prefill: a chunk
    scatters exactly the slice of the image run it covers.
    Returns (last_token_logits (B,vocab), new_caches).
    """
    x, positions = T.embed_inputs(params, cfg, tokens, mm_embeds)
    if prefix_len is not None:
        if lengths is None:
            raise ValueError("suffix prefill requires lengths")
        idx = jnp.arange(x.shape[1], dtype=jnp.int32)[None]
        abspos = pos_base.astype(jnp.int32) + idx
        valid = (abspos >= prefix_len) & (abspos < lengths[:, None])
        positions = jnp.where(valid, abspos, -1)
    elif lengths is not None:
        idx = jnp.arange(x.shape[1], dtype=jnp.int32)[None]
        positions = jnp.where(idx < lengths[:, None], idx, -1)
    if mm_feats is not None:
        # padded/invalid positions are -1, hence rel < 0 -> untouched
        x = T.scatter_mm_features(x, positions, mm_feats, mm_start)
    enc_out = None
    if cfg.encoder is not None:
        enc_out = T.run_encoder(params, cfg, enc_frames)
    h, new_caches, _ = T.run_decoder(params, cfg, x, positions, caches=caches,
                                     enc_out=enc_out, prefix_len=prefix_len,
                                     pos_base=pos_base)
    if prefix_len is not None:
        last = jnp.clip(lengths - 1 - pos_base.astype(jnp.int32), 0)
        new_caches["len"] = lengths
    elif lengths is not None:
        last = jnp.clip(lengths - 1, 0)
        new_caches["len"] = lengths
    else:
        last = jnp.full((x.shape[0],), x.shape[1] - 1, jnp.int32)
        new_caches["len"] = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)  # (B,1,d)
    logits = T.lm_logits(params, cfg, h_last)[:, 0]
    return logits.astype(jnp.float32), new_caches


def decode_forward(params, cfg: ModelConfig, tokens, caches):
    """One decode step. tokens: (B,) int32 previous tokens.

    Position of the new token is caches['len'] (per row). Returns
    (logits (B,vocab), new_caches).
    """
    positions = caches["len"][:, None].astype(jnp.int32)          # (B,1)
    x = params["embed"][tokens[:, None]]
    x = shard(x, "batch", None, "act_embed")
    h, new_caches, _ = T.run_decoder(params, cfg, x, positions, caches=caches)
    logits = T.lm_logits(params, cfg, h)[:, 0]
    return logits.astype(jnp.float32), new_caches


# re-exports for convenience
__all__ = [
    "train_forward", "prefill_forward", "decode_forward",
    "init_params", "abstract_params", "param_pspecs", "param_structure",
]

"""Jit'd public wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import dispatch
from repro.kernels.ssd_scan.kernel import ssd_scan as _kernel
from repro.kernels.ssd_scan.ref import ssd_ref, ssd_sequential


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, d_skip, chunk: int, init_state=None, *,
             interpret: Optional[bool] = None):
    if interpret is None:
        interpret = dispatch.interpret()
    return _kernel(x, dt, a, b, c, d_skip, chunk, init_state,
                   interpret=interpret)


__all__ = ["ssd_scan", "ssd_ref", "ssd_sequential"]

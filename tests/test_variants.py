"""Beyond-paper optimization variants: fp8 KV, FSDP rules, chunked CE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.model import (decode_forward, init_params, prefill_forward,
                                train_forward)
from repro.models.params import param_pspecs
from repro.models.partitioning import tp_rules
from repro.models.transformer import cache_pspecs, make_caches


def test_fp8_kv_cache_greedy_agreement():
    """fp8 KV storage must not change greedy decoding on a small model."""
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    seqs = {}
    for name, kvd in [("f32", jnp.float32), ("fp8", jnp.float8_e4m3fn)]:
        c = make_caches(cfg, B, 32, dtype=jnp.float32, kv_dtype=kvd)
        lg, c = prefill_forward(params, cfg, toks, c,
                                lengths=jnp.array([S] * B))
        t = jnp.argmax(lg, -1)
        out = []
        for _ in range(5):
            lg, c = decode_forward(params, cfg, t, c)
            t = jnp.argmax(lg, -1)
            out.append(np.asarray(t))
        seqs[name] = np.stack(out)
    # a randomly-initialized 2-layer model has near-uniform logits, so fp8
    # rounding can flip a few argmaxes — require majority agreement
    agree = (seqs["f32"] == seqs["fp8"]).mean()
    assert agree >= 0.6, agree


def test_fp8_engine_end_to_end():
    """The fp8-KV optimization composes with the serving engine."""
    from repro.serving.engine import Engine
    from repro.serving.request import Request
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_len=48,
                 kv_dtype=jnp.float8_e4m3fn)
    r = Request(prompt_tokens=[3, 1, 4, 1, 5], max_new_tokens=6)
    out = eng.run_request(r)
    assert len(out) == 6


def _no_duplicate_axes(spec):
    seen = []
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            assert a not in seen, f"duplicate mesh axis {a} in {spec}"
            seen.append(a)


@pytest.mark.parametrize("kw", [
    {}, {"fsdp": True}, {"expert_parallel": True},
    {"fsdp": True, "expert_parallel": True}, {"decode_kv": "seq"},
    {"multi_pod": True},
])
@pytest.mark.parametrize("arch", ["glm4-9b", "llama4-scout-17b-a16e",
                                  "jamba-v0.1-52b", "mamba2-370m",
                                  "whisper-base"])
def test_rule_sets_produce_valid_pspecs(arch, kw):
    """Every rules variant must yield PartitionSpecs without duplicate mesh
    axes for every parameter and cache of every arch family."""
    from jax.sharding import PartitionSpec as P
    cfg = get_config(arch)
    rules = tp_rules(axis_sizes={"data": 16, "model": 16, "pod": 2}, **kw)
    is_p = lambda x: isinstance(x, P)
    for spec in jax.tree.leaves(param_pspecs(cfg, rules), is_leaf=is_p):
        _no_duplicate_axes(spec)
    for spec in jax.tree.leaves(cache_pspecs(cfg, rules), is_leaf=is_p):
        if is_p(spec):
            _no_duplicate_axes(spec)


def test_chunked_ce_matches_dense():
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    key = jax.random.PRNGKey(2)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    l1, _ = train_forward(params, cfg, batch, remat=False, loss_chunk=0)
    l2, _ = train_forward(params, cfg, batch, remat=False, loss_chunk=8)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    # gradients too
    g1 = jax.grad(lambda p: train_forward(p, cfg, batch, remat=False)[0])(
        params)
    g2 = jax.grad(lambda p: train_forward(p, cfg, batch, remat=False,
                                          loss_chunk=8)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)

"""Continuous-batching instance engine with REAL JAX execution.

One ``Engine`` is one serving instance (a Prefill, Decode or fused PD
instance in EPD-Serve terms). It owns a slot-based decode batch and a KV
cache; requests are prefillled one-at-a-time (batch 1) and inserted into a
free slot, then all active slots decode in lock-step — the standard
continuous-batching loop, scaled to CPU-sized configs for tests/examples.

The EPD disaggregation layer (repro.core) drives one or more Engines: the
Encode stage produces features into the MM Store, Prefill engines run
``prefill_request`` and export their caches, Decode engines import caches
via ``insert`` and run ``decode_step``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import frontend as FE
from repro.models.transformer import make_caches
from repro.serving.request import Request
from repro.serving.steps import make_decode_fn, make_insert_fn, make_prefill_fn


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 128, temperature: float = 0.0,
                 cache_dtype=jnp.float32, kv_dtype=None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.kv_dtype = kv_dtype          # e.g. jnp.float8_e4m3fn (§Perf)
        self._prefill = make_prefill_fn(cfg)
        self._decode = make_decode_fn(cfg, temperature)
        self._insert = make_insert_fn(cfg)
        self.caches = make_caches(cfg, max_batch, max_len, dtype=cache_dtype,
                                  kv_dtype=kv_dtype)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self._last_tok = np.zeros((max_batch,), np.int32)
        self._key = jax.random.PRNGKey(0)

    # -- capacity ------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.slots)

    # -- stages --------------------------------------------------------------
    def prefill_request(self, req: Request, mm_embeds=None,
                        enc_frames=None) -> Tuple[int, Dict[str, Any]]:
        """Run Prefill for one request (batch=1). Returns (first_token,
        prefilled_caches) — the caches are the P->D payload."""
        cfg = self.cfg
        n_mm = 0
        if mm_embeds is not None and cfg.encoder is None:
            n_mm = mm_embeds.shape[1]
        toks = np.asarray(req.prompt_tokens, np.int32)[None]
        pad = self.max_len - n_mm - toks.shape[1]
        if pad < 0:
            raise ValueError(
                f"prompt ({toks.shape[1]}+{n_mm}) exceeds max_len {self.max_len}")
        toks = np.pad(toks, ((0, 0), (0, pad)))
        lengths = jnp.asarray([len(req.prompt_tokens) + n_mm], jnp.int32)
        caches = make_caches(cfg, 1, self.max_len, dtype=self.cache_dtype,
                             kv_dtype=self.kv_dtype)
        logits, caches = self._prefill(self.params, jnp.asarray(toks),
                                       lengths, caches, mm_embeds, enc_frames)
        first = int(jnp.argmax(logits[0]))
        return first, caches

    def insert(self, req: Request, prefilled_caches, first_token: int) -> int:
        """Attach a prefilled request to a free decode slot (P->D import)."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free decode slot")
        slot = free[0]
        self.caches = self._insert(prefilled_caches, self.caches, slot)
        self.slots[slot] = req
        self._last_tok[slot] = first_token
        req.output_tokens.append(first_token)
        return slot

    def decode_step(self) -> List[Tuple[Request, int, bool]]:
        """One lock-step decode over all slots. Returns (req, token, done)
        for every ACTIVE slot (inactive slots compute but are ignored)."""
        self._key, sub = jax.random.split(self._key)
        toks, self.caches = self._decode(
            self.params, jnp.asarray(self._last_tok), self.caches, sub)
        toks = np.asarray(toks)
        out = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            t = int(toks[i])
            self._last_tok[i] = t
            req.output_tokens.append(t)
            done = (t == req.eos_token or
                    len(req.output_tokens) >= req.max_new_tokens or
                    int(np.asarray(self.caches["len"][i])) >= self.max_len - 1)
            if done:
                self.slots[i] = None
            out.append((req, t, done))
        return out

    # -- monolithic convenience (the vLLM-style baseline) ---------------------
    def run_request(self, req: Request) -> List[int]:
        """Serial E->P->D for one request on this single engine."""
        mm = None
        enc = None
        cfg = self.cfg
        if req.is_multimodal and cfg.frontend is not None:
            feats = FE.stub_embeddings(cfg, req.mm_payload,
                                       req.mm_tokens or None)
            if cfg.encoder is not None:
                enc = feats[None]
            else:
                mm = feats[None]
        first, caches = self.prefill_request(req, mm, enc)
        self.insert(req, caches, first)
        while any(s is req for s in self.slots):
            self.decode_step()
        return req.output_tokens

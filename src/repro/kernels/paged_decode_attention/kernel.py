"""Pallas TPU paged decode attention: one query token vs. a paged KV pool.

Flash-decode over a block table instead of a contiguous cache. The KV
pool is a flat array of fixed-size pages shared by all slots; each
slot's block table row names the physical page of every logical page.
The page dimension is the innermost (sequential) grid axis and the
block table + per-slot lengths ride in via scalar prefetch, so the
pipeline's k/v index map resolves the *physical* page to DMA before the
kernel body runs.

HBM traffic is proportional to each slot's ACTUAL length, not the pool
or table width: for grid steps past the slot's last page the index map
clamps to the last real page — Pallas elides the DMA when consecutive
grid steps map the same block — and the compute is skipped with
``pl.when``. This is the Decode-stage hot loop of the disaggregated
serving system; arithmetic intensity ~= GQA group size, exactly as the
dense decode kernel, but without streaming `max_len` KV for short
sequences.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, window: Optional[int],
            page: int, n_pages_max: int):
    bi = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[bi]                               # valid tokens incl. q
    n_pages = (length + page - 1) // page

    @pl.when(j < n_pages)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (g, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (page, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        qpos = length - 1
        valid = kpos < length                          # per-slot length mask
        if window is not None:
            valid &= kpos > qpos - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(valid, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_pages_max - 1)
    def _done():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, block_tbl, lengths, *,
                           window: Optional[int] = None,
                           interpret: bool = False):
    """q: (b, nq, hd); k_pool, v_pool: (P, page, nkv, hd);
    block_tbl: (b, max_pages) int32; lengths: (b,) int32 valid tokens
    including the current one. Returns (b, nq, hd)."""
    b, nq, hd = q.shape
    page, nkv = k_pool.shape[1], k_pool.shape[2]
    g = nq // nkv
    n_pages_max = block_tbl.shape[1]

    qg = q.reshape(b, nkv, g, hd)
    tbl = block_tbl.astype(jnp.int32)
    lens = lengths.astype(jnp.int32)

    def kv_page_index(bi, h, j, tbl_ref, len_ref):
        # Clamp trailing grid steps to the slot's LAST real page so the
        # pipeline re-maps the same block (no fresh DMA) once past the
        # actual length; compute for those steps is masked off above.
        n_pages = (len_ref[bi] + page - 1) // page
        jj = jnp.minimum(j, jnp.maximum(n_pages - 1, 0))
        return (tbl_ref[bi, jj], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nkv, n_pages_max),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, h, j, t, s: (bi, h, 0, 0)),
            pl.BlockSpec((1, page, 1, hd), kv_page_index),
            pl.BlockSpec((1, page, 1, hd), kv_page_index),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, h, j, t, s: (bi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    kern = functools.partial(_kernel, scale=hd ** -0.5, window=window,
                             page=page, n_pages_max=n_pages_max)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, hd), q.dtype),
        interpret=interpret,
    )(tbl, lens, qg, k_pool, v_pool)
    return out.reshape(b, nq, hd)

"""Pure-jnp oracle for the decode-attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, q_pos, kv_pos,
                         *, window: Optional[int] = None) -> jax.Array:
    """Single-token GQA attention over a (ring-buffer) KV cache.

    q: (b, nq, hd) — the one new token's queries.
    k, v: (b, S, nkv, hd); kv_pos: (b, S) absolute positions, -1 = empty.
    q_pos: (b,) the token's absolute position.
    Returns (b, nq, hd).
    """
    b, nq, hd = q.shape
    nkv = k.shape[2]
    g = nq // nkv
    qg = q.reshape(b, nkv, g, hd)
    scores = jnp.einsum("bkgh,bTkh->bkgT", qg, k,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    valid = (kv_pos >= 0) & (kv_pos <= q_pos[:, None])
    if window is not None:
        valid &= kv_pos > (q_pos[:, None] - window)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgT,bTkh->bkgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, nq, hd).astype(q.dtype)

"""openPangu-7B-VL — the paper's own evaluation model (ViT 0.7B + LLM 7B).

No public model card exists; geometry is estimated from the paper:
Table 3 shows E->P transmitted features of shape [n, 3584], so the
projected feature dim (= LLM d_model) is 3584; a 720x1280 image encodes
to 1196 tokens. The 7B LLM geometry is taken as the standard 7B-class
layout at d_model=3584. Marked ESTIMATED in DESIGN.md.
"""
from repro.configs.base import FrontendConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="openpangu-7b-vl",
    arch_type="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    pattern=(LayerSpec("attn", "mlp"),),
    frontend=FrontendConfig(kind="vision", tokens_per_item=1196,  # 720p
                            feature_dim=1280),
    rope_theta=1_000_000.0,
    source="paper (EPD-Serve) — ESTIMATED geometry",
)

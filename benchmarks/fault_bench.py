"""Fault-injection chaos benchmark: recovery vs recovery-off.

Three halves, all seeded and deterministic:

1. REAL cluster (serial driver): a 2-decode-instance FT cluster under a
   seeded fault plan (transfer wire loss + one armed mid-run
   decode-instance crash) must complete 100% of requests with greedy
   outputs BIT-IDENTICAL to the zero-fault run (crash victims re-route
   to the surviving instance; the re-prefill rides the prefix cache).
   The same plan with recovery disabled loses requests — surfaced,
   never silent.

2. CONTINUOUS mode: the same chaos (5% wire loss + one armed crash)
   through ``run_continuous`` — the iteration-level scheduler absorbs
   transfer faults as retry-parked jobs and the crash as re-prefill
   work items on the survivor. 100% completion, bit-identical to the
   ZERO-FAULT CONTINUOUS run, and the modeled throughput retention
   (zero-fault makespan / chaos makespan) is recorded.

3. Simulator sweep: 1% / 5% per-group transfer loss on the EPD
   simulator. With recovery, every request completes and the p99 TTFT
   inflation stays bounded (retry time is charged through the
   CostModel into latency accounting); recovery-off loses requests.

Emits a BENCH_faults.json snapshot next to the repo root so the
fault-tolerance trajectory is recorded per PR.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List

# p99 TTFT with recovery may inflate by at most this factor over the
# zero-fault run at the swept loss rates (retries cost link time only)
MAX_P99_TTFT_INFLATION = 1.5


def bench_faults() -> List[str]:
    import jax
    from repro.configs import get_config
    from repro.core.cluster import EPDCluster
    from repro.core.faults import (SITE_DECODE_CRASH, SITE_TRANSFER_WIRE,
                                   ArmedFault, FaultPlan)
    from repro.core.simulator import SHAREGPT_4O, simulate
    from repro.core.telemetry import Tracer
    from repro.models.model import init_params
    from repro.serving.request import Request

    rows = ["faults,value,derived"]
    snap = {"config": {"seed": 7, "crash_site": "decode.crash",
                       "wire_rates": [0.01, 0.05]},
            "cluster": {}, "sweep": []}

    # ---- REAL cluster: crash + wire faults, bit-identical recovery ----
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))

    def reqs():
        return [Request(prompt_tokens=list(range(3 + i, 20 + i)),
                        max_new_tokens=8) for i in range(4)]

    def run(faults=None, recovery=True, tracer=None):
        cl = EPDCluster(cfg, params, max_batch=2, max_len=64, paged=True,
                        page_size=8, prefix_cache=True, n_decode=2,
                        chunked_prefill=True, prefill_chunk=8,
                        faults=faults, recovery=recovery, tracer=tracer)
        rs = reqs()
        for r in rs:
            cl.submit(r)
        done = cl.run_until_done()
        return cl, rs, done

    _, ref, _ = run()                       # zero-fault reference
    # wire rate high enough that the small run draws real retries (the
    # retry-reconciliation assert below needs a nonzero ledger)
    plan = FaultPlan(seed=7, rates={SITE_TRANSFER_WIRE: 0.3},
                     armed=[ArmedFault(SITE_DECODE_CRASH, key=(0, 3))])
    tracer = Tracer(enabled=True)
    ft, got, done = run(faults=plan, tracer=tracer)
    assert not ft.report.lost, "FT cluster must lose nothing"
    assert len(done) == len(ref), "FT cluster must complete 100%"
    assert ft.report.instance_crashes == 1
    assert ft.report.reroutes >= 1, "crash victims must re-route"
    for a, b in zip(ref, got):
        assert a.output_tokens == b.output_tokens, \
            "recovery must keep greedy outputs bit-identical"
    for i in ft.live_decode_indices():
        ft.decode_engines[i].assert_no_page_leaks()

    # ---- per-request latency attribution (telemetry invariants) ----
    # every chaos-run request decomposes into queue/compute/transfer/
    # swap/retry on one accounting clock, the components sum to the e2e
    # measurement, and the retry component reconciles exactly with the
    # registry's retry-time counter (both ledgers see the same charges)
    tracer.assert_balanced()
    ft.acc.assert_all_closed()
    ft.acc.check_all(tol=0.01)
    att = ft.attribution()
    retry_comp = ft.acc.component_total("retry")
    assert abs(retry_comp - ft.report.retry_time_total) <= 1e-9, (
        f"retry component {retry_comp} != "
        f"retry_time_total {ft.report.retry_time_total}")

    off, _, off_done = run(faults=plan, recovery=False)
    assert off.report.lost, "recovery-off baseline must lose requests"
    assert len(off_done) + len(off.report.lost) == len(ref)

    snap["cluster"] = {
        "n_requests": len(ref), "crashes": ft.report.instance_crashes,
        "reroutes": ft.report.reroutes,
        "transfer_retries": ft.report.transfer_retries,
        "retry_time_ms": round(ft.report.retry_time_total * 1e3, 3),
        "bit_identical": True, "ft_lost": 0,
        "recovery_off_lost": len(off.report.lost),
    }
    snap["attribution"] = att
    snap["telemetry"] = ft.metrics.snapshot()
    rows.append(
        f"cluster_attribution,sum_eq_e2e,"
        f"retry_{round(retry_comp * 1e3, 2)}ms=="
        f"retry_time_total_{round(ft.report.retry_time_total * 1e3, 2)}ms")
    rows.append(
        f"cluster_crash_reroute,bit_identical,"
        f"{ft.report.instance_crashes}_crash_{ft.report.reroutes}_"
        f"reroutes_0_lost_vs_{len(off.report.lost)}_lost_off")

    # ---- CONTINUOUS mode: chaos through the iteration scheduler ----
    def run_cont(faults=None, recovery=True):
        cl = EPDCluster(cfg, params, max_batch=2, max_len=64, paged=True,
                        page_size=8, prefix_cache=True, n_decode=2,
                        chunked_prefill=True, prefill_chunk=8,
                        faults=faults, recovery=recovery)
        rs = reqs()
        done = cl.run_continuous(rs)
        return cl, rs, done

    c_base, c_ref, _ = run_cont()           # zero-fault continuous
    t_base = c_base.continuous_timeline.makespan
    cont_plan = FaultPlan(seed=7, rates={SITE_TRANSFER_WIRE: 0.05},
                          armed=[ArmedFault(SITE_DECODE_CRASH,
                                            key=(0, 8))])
    c_ft, c_got, c_done = run_cont(faults=cont_plan)
    assert len(c_done) == len(c_ref) and not c_ft.report.lost, \
        "continuous FT must complete 100%"
    assert c_ft.report.instance_crashes == 1
    for a, b in zip(c_ref, c_got):
        assert a.output_tokens == b.output_tokens, \
            "continuous recovery must keep greedy outputs bit-identical"
    c_ft.prefill_engine.assert_no_page_leaks()
    for i in c_ft.live_decode_indices():
        c_ft.decode_engines[i].assert_no_page_leaks()
    c_ft.acc.assert_all_closed()
    t_chaos = c_ft.continuous_timeline.makespan
    retention = t_base / t_chaos
    c_off, _, c_off_done = run_cont(faults=cont_plan, recovery=False)
    assert len(c_off_done) + len(c_off.report.lost) == len(c_ref)

    snap["continuous"] = {
        "n_requests": len(c_ref),
        "zero_fault_makespan_ms": round(t_base * 1e3, 3),
        "chaos_makespan_ms": round(t_chaos * 1e3, 3),
        "throughput_retention": round(retention, 3),
        "crashes": c_ft.report.instance_crashes,
        "reroutes": c_ft.report.reroutes,
        "retry_parks": c_ft.metrics.total("sched_retry_parks_total"),
        "bit_identical": True, "ft_lost": 0,
        "recovery_off_lost": len(c_off.report.lost),
    }
    rows.append(
        f"continuous_chaos,bit_identical_100pct,"
        f"retention_x{retention:.2f}_"
        f"{c_ft.report.instance_crashes}_crash_"
        f"{c_ft.report.reroutes}_reroutes_vs_"
        f"{len(c_off.report.lost)}_lost_off")

    # ---- simulator: transfer-loss sweep with charged retry time ----
    model = get_config("openpangu-7b-vl")
    ds = dataclasses.replace(SHAREGPT_4O, mm_fraction=0.25,
                             output_tokens=64)
    kw = dict(rate=24.0, n_requests=40, seed=3, kv_page_tokens=16)
    base = simulate(model, "E-P-D", ds, **kw)
    for rate in (0.01, 0.05):
        fp = FaultPlan(seed=7, rates={SITE_TRANSFER_WIRE: rate})
        ft = simulate(model, "E-P-D", ds, faults=fp, **kw)
        off = simulate(model, "E-P-D", ds, faults=fp,
                       fault_recovery=False, **kw)
        assert ft.lost_requests == 0, \
            f"recovery must lose nothing at {rate:.0%}"
        assert ft.completed_requests == kw["n_requests"]
        assert ft.transfer_retries > 0, "the sweep must exercise retries"
        infl = ft.p99_ttft_ms / base.p99_ttft_ms
        assert infl <= MAX_P99_TTFT_INFLATION, \
            f"p99 TTFT inflated {infl:.2f}x at {rate:.0%} loss"
        assert off.lost_requests > 0, \
            f"recovery-off must lose requests at {rate:.0%}"
        snap["sweep"].append({
            "wire_loss_rate": rate,
            "base_p99_ttft_ms": round(base.p99_ttft_ms, 2),
            "ft_p99_ttft_ms": round(ft.p99_ttft_ms, 2),
            "p99_ttft_inflation": round(infl, 3),
            "ft_transfer_retries": ft.transfer_retries,
            "ft_retry_time_ms": round(ft.retry_time_ms, 2),
            "ft_mean_components_ms": ft.attribution["mean_components_ms"],
            "ft_lost": ft.lost_requests,
            "off_lost": off.lost_requests,
        })
        rows.append(
            f"sim_wire_loss_{int(rate * 100)}pct,"
            f"0_lost_p99ttft_x{infl:.2f},"
            f"{ft.transfer_retries}_retries_"
            f"{ft.retry_time_ms:.1f}ms_charged_vs_"
            f"{off.lost_requests}_lost_off")

    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_faults.json")
    with open(os.path.abspath(out), "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows


if __name__ == "__main__":
    for row in bench_faults():
        print(row)

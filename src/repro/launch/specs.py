"""Dry-run step builders: (step_fn, abstract args, shardings) per
(architecture x input shape).

Everything is ShapeDtypeStruct — no device allocation. The same builders
drive real execution when given concrete arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models.model import decode_forward, prefill_forward
from repro.models.params import abstract_params, param_pspecs
from repro.models.partitioning import ShardingRules, tp_rules, use_rules
from repro.models.transformer import cache_pspecs, make_caches
from repro.training.optimizer import AdamW, AdamWState
from repro.training.train import make_train_step

# default microbatching for the train_4k shape (global_batch=256):
# micro=16 keeps per-micro logits (16 x 4096 x vocab f32) within HBM.
TRAIN_MICROBATCHES = 16


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _text_and_mm(cfg: ModelConfig, shape: InputShape) -> Tuple[int, int]:
    """Split the input shape's seq_len into (text_tokens, mm_tokens)."""
    if cfg.frontend is not None and cfg.encoder is None:
        n_mm = min(cfg.frontend.tokens_per_item, shape.seq_len // 2)
        return shape.seq_len - n_mm, n_mm
    return shape.seq_len, 0


def train_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b = shape.global_batch
    s_text, n_mm = _text_and_mm(cfg, shape)
    batch = {
        "tokens": _sds((b, s_text), jnp.int32),
        "labels": _sds((b, s_text), jnp.int32),
    }
    if n_mm:
        batch["mm_embeds"] = _sds((b, n_mm, cfg.frontend.feature_dim),
                                  jnp.bfloat16)
    if cfg.encoder is not None:
        batch["enc_frames"] = _sds(
            (b, cfg.encoder.n_ctx, cfg.frontend.feature_dim), jnp.bfloat16)
    return batch


def batch_pspecs(batch: Dict[str, Any], rules: ShardingRules):
    out = {}
    for k, v in batch.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = rules.spec(axes)
    return out


@dataclasses.dataclass
class DryrunCase:
    """One (arch x shape) lowering case."""
    fn: Any                       # callable to jit
    args: Tuple[Any, ...]         # abstract args
    in_specs: Tuple[Any, ...]     # PartitionSpec pytrees matching args
    donate: Tuple[int, ...] = ()


def train_plan(rules: ShardingRules, shape: InputShape):
    """(num_microbatches, loss_chunk) for a train case under these rules.

    FSDP variants spread the batch wide and ZeRO-3 weights re-gather per
    microbatch — so they run few/one microbatch(es) and bound logits with
    chunked CE instead. The MoE hybrid (batch on 'data' only) keeps 4
    microbatches to cap saved-activation memory while amortizing gathers.
    """
    bsz = rules.size("batch")
    if bsz >= shape.global_batch:            # dense FSDP: 1 row / device
        return 1, 512
    if rules.size("embed") > 1:              # ZeRO-3 hybrid (MoE)
        return 4, 512
    # baseline TP: each microbatch must still shard over the batch axes
    # (multi-pod: 32-way), else the sanitizer replicates the whole batch
    n_micro = min(TRAIN_MICROBATCHES, max(shape.global_batch // bsz, 1))
    return n_micro, 512 if n_micro < TRAIN_MICROBATCHES else 0


def build_case(cfg: ModelConfig, shape: InputShape,
               rules: ShardingRules, *, kv_dtype=None) -> DryrunCase:
    params = abstract_params(cfg, jnp.bfloat16)
    p_specs = param_pspecs(cfg, rules)

    if shape.kind == "train":
        opt = AdamW()
        opt_state = AdamWState(
            _sds((), jnp.int32),
            jax.tree.map(lambda x: _sds(x.shape, jnp.float32), params),
            jax.tree.map(lambda x: _sds(x.shape, jnp.float32), params))
        o_specs = AdamWState(P(), p_specs, p_specs)
        batch = train_inputs(cfg, shape)
        b_specs = batch_pspecs(batch, rules)
        n_micro, loss_chunk = train_plan(rules, shape)
        # under ZeRO-style weight sharding, pin the grad accumulator to the
        # param shards so per-micro grads reduce-scatter instead of
        # all-reducing at full size (EXPERIMENTS.md §Perf pair 2, iter 3)
        grad_specs = p_specs if (rules.size("embed") > 1 and n_micro > 1) \
            else None
        step = make_train_step(cfg, opt, remat=True,
                               num_microbatches=n_micro,
                               loss_chunk=loss_chunk,
                               grad_specs=grad_specs)

        def fn(params, opt_state, batch):
            with use_rules(rules):
                return step(params, opt_state, batch)

        return DryrunCase(fn, (params, opt_state, batch),
                          (p_specs, o_specs, b_specs), donate=(0, 1))

    if shape.kind == "prefill":
        b = shape.global_batch
        s_text, n_mm = _text_and_mm(cfg, shape)
        caches = make_caches(cfg, b, shape.seq_len, abstract=True)
        c_specs = cache_pspecs(cfg, rules)
        tokens = _sds((b, s_text), jnp.int32)
        lengths = _sds((b,), jnp.int32)
        mm = (_sds((b, n_mm, cfg.frontend.feature_dim), jnp.bfloat16)
              if n_mm else None)
        enc = (_sds((b, cfg.encoder.n_ctx, cfg.frontend.feature_dim),
                    jnp.bfloat16) if cfg.encoder is not None else None)

        def fn(params, tokens, lengths, caches, mm_embeds, enc_frames):
            with use_rules(rules):
                return prefill_forward(params, cfg, tokens, caches,
                                       lengths=lengths, mm_embeds=mm_embeds,
                                       enc_frames=enc_frames)

        bspec = rules.spec(("batch", None))
        mm_spec = rules.spec(("batch", None, None)) if mm is not None else None
        enc_spec = rules.spec(("batch", None, None)) if enc is not None else None
        return DryrunCase(
            fn, (params, tokens, lengths, caches, mm, enc),
            (p_specs, bspec, rules.spec(("batch",)), c_specs, mm_spec,
             enc_spec),
            donate=(3,))

    # decode
    b = shape.global_batch
    caches = make_caches(cfg, b, shape.seq_len, abstract=True,
                         for_decode=True, kv_dtype=kv_dtype)
    c_specs = cache_pspecs(cfg, rules)
    tokens = _sds((b,), jnp.int32)

    def fn(params, tokens, caches):
        with use_rules(rules):
            return decode_forward(params, cfg, tokens, caches)

    return DryrunCase(fn, (params, tokens, caches),
                      (p_specs, rules.spec(("batch",)), c_specs),
                      donate=(2,))


def decode_supported(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """Returns a skip reason, or None if the (arch x shape) pair runs.

    long_500k requires sub-quadratic decode memory (DESIGN.md §4): pure
    full-attention archs are skipped; SSM / SSM-dominant hybrid / SWA run.
    """
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention decode at 524k KV is out of scope "
                "(no sliding-window/block-sparse variant for this arch)")
    return None

"""Continuous-batching instance engine with REAL JAX execution.

One ``Engine`` is one serving instance (a Prefill, Decode or fused PD
instance in EPD-Serve terms). It owns a slot-based decode batch and a KV
cache; requests are prefillled one-at-a-time (batch 1) and inserted into a
free slot, then all active slots decode in lock-step — the standard
continuous-batching loop, scaled to CPU-sized configs for tests/examples.

Two KV layouts:

* dense (default) — per-slot contiguous caches (batch, max_len, ...);
  insert copies the request's whole cache row into its slot.
* paged (``paged=True``) — attention KV lives in a shared page pool with
  per-slot block tables (serving.kv_pool, ref-counted pages). Prefill
  writes straight into pool pages, so insert on the SAME engine is a pure
  block-table handoff (zero KV bytes moved) and insert from ANOTHER
  engine moves only the request's pages. Decode attention gathers KV
  through the block table with per-slot length masking, so HBM traffic
  tracks actual lengths.
* paged + ``prefix_cache=True`` — a radix-tree prefix cache
  (serving.prefix_cache) indexes pool pages by their token content.
  ``prefill_request`` reuses the longest cached prefix by ref-counting
  its shared pages into the request's block table and computes only the
  unshared suffix; a match ending inside a page is copied on write so
  shared pages are never mutated. Finished prefills are retained in the
  tree and evicted LRU under pool pressure. Requires an attention-only
  decoder (no SSM state / cross-attention to reconstruct mid-sequence)
  and applies to text-only requests.
* paged + ``chunked_prefill=True`` — long prompts prefill in fixed-size
  chunks of ``prefill_chunk`` tokens (a page multiple): each chunk
  allocates only its own pages, scatters them into the pool as it
  finishes, and attends over chunks 0..k-1 through the block table (the
  same gather-prefix path the prefix cache uses, with the chunk start as
  ``pos_base`` and the tokens already resident as ``prefix_len``). The
  in-flight prefill window is O(chunk) instead of O(prompt), and the
  P->D payload records per-chunk segments so the transfer planner can
  stream chunk *k*'s pages while chunk *k+1* computes
  (kv_transfer.plan_chunked). Composes with the prefix cache — a cached
  prefix skips whole leading chunks. Same attention-only/text-only
  constraints as the prefix cache; multimodal requests fall back to the
  monolithic paged path.

* paged + ``preemption=True`` — KV pressure (decode growth past page
  boundaries, cross-engine insert admission) no longer kills with a pool
  error: a victim slot (lowest priority, fewest private pages lost,
  never the last active one, starvation-guarded) is preempted at page
  granularity — prefix-shared pages are unref'd back to the tree,
  private pages are swapped to the pool's host backing store — and the
  request parks until ``decode_step`` can re-fault it: shared pages are
  re-ref'd from the tree (or recomputed if evicted meanwhile), private
  pages swap back in, and decode resumes from the exact saved position.
  Greedy outputs are bit-identical to an uninterrupted run.

The EPD disaggregation layer (repro.core) drives one or more Engines: the
Encode stage produces features into the MM Store, Prefill engines run
``prefill_request`` and export their caches, Decode engines import caches
via ``insert`` and run ``decode_step``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batching import BatchPlan, IterationScheduler, PrefillJob
from repro.core.faults import (FaultInjector, InstanceDown, NoFreeSlot,
                               SwapLost)
from repro.core.scheduler import VictimCandidate, pick_preemption_victim
from repro.core.telemetry import (NULL_TRACER, LatencyAccountant,
                                  MetricsRegistry, Tracer)
from repro.models import frontend as FE
from repro.models.transformer import make_caches
from repro.serving.kv_pool import (PagePool, PagedKVPayload, PoolExhausted,
                                   SwapHandle)
from repro.serving.prefix_cache import MatchResult, PrefixCache
from repro.serving.request import Request
from repro.serving.steps import (make_decode_fn, make_encode_fn,
                                 make_insert_fn, make_page_copy_fn,
                                 make_page_gather_fn, make_page_scatter_fn,
                                 make_paged_insert_fn,
                                 make_pool_page_copy_fn, make_prefill_fn)


@dataclass
class PreemptedRequest:
    """A decode request parked off-device by page-level preemption.

    handle         — swap ticket for the private pages (KV content on the
                     host; None when every page was tree-shared).
    n_shared_pages — leading block-table pages that were shared with the
                     prefix tree at preemption time: they were unref'd,
                     not swapped, and are re-ref'd (or recomputed, if the
                     tree evicted them meanwhile) on resume.
    n_pages        — total pages the block table held (shared + private).
    side           — host copies of the slot's side state (ssm/cross/len)
                     as batch-1 arrays, restored via the insert step.
    last_tok       — the token the next decode step must feed.
    """

    req: Request
    handle: Optional[SwapHandle]
    n_shared_pages: int
    n_pages: int
    side: Dict[str, Any] = field(default_factory=dict)
    last_tok: int = 0
    t_parked: float = 0.0             # tracer clock at park (parked span)


class PrefillTask:
    """One request's chunked prefill as a resumable state machine.

    The serial path (``Engine._prefill_chunked``) drives it to completion
    in a tight loop; the continuous path (``Engine.step`` /
    ``EPDCluster.run_continuous``) interleaves ``run_chunk`` calls across
    tasks so the device stays busy between one request's chunks. Either
    driver executes the exact same sequence of pool allocations and
    jitted suffix-prefill calls for a given request, so greedy outputs
    are bit-identical by construction.

    Multimodal (scatter-path) requests carry the E->P feature-arrival
    barrier as task state: a chunk whose window lies entirely before the
    image run scatters nothing (``needs_features_next`` is False) and may
    run before the features land; the first chunk overlapping the run
    requires ``supply_features`` first. ``defer_features=True`` suppresses
    the init-time encode-skip validation for exactly that case — the
    barrier check in ``run_chunk`` enforces it instead.

    Lifecycle: construct (takes the prefix-cache match refs), zero or
    more ``run_chunk`` (each takes its own page refs; a
    :class:`PoolExhausted` from the allocator leaves the task state
    untouched and retryable), then exactly one of ``finish`` (refs move
    to the returned payload) or ``abort`` (every ref unwound). In-flight
    tasks register with the engine so ``page_holders`` audits their refs.
    """

    def __init__(self, eng: "Engine", req: Request, n_tokens: int,
                 mm_feats=None, mm_key=None, defer_features: bool = False):
        self.eng = eng
        self.req = req
        self.n_tokens = n_tokens
        self.mm_key = mm_key
        page = eng.page_size
        self.page = page
        self.C = eng.prefill_chunk if eng.chunked_prefill else eng.max_len
        width = eng.max_len // page
        # multimodal: the prefix-cache KEY splices a hash-derived
        # pseudo-token run over the image segment — (mm-content-hash,
        # token-run) — so identical image+prompt pairs match; the FEED
        # tokens carry placeholder 0s there (their embeddings are
        # overwritten by the mm_feats scatter, never looked at).
        p_toks = list(req.prompt_tokens)
        self.n_mm = n_tokens - len(p_toks) if mm_key is not None else 0
        if mm_key is not None:
            self.key_tokens = (p_toks[:req.mm_pos]
                               + FE.mm_key_run(mm_key, self.n_mm)
                               + p_toks[req.mm_pos:])
            self.feed_tokens = (p_toks[:req.mm_pos] + [0] * self.n_mm
                                + p_toks[req.mm_pos:])
        else:
            self.key_tokens = self.feed_tokens = p_toks
        if eng.prefix_cache is not None:
            # cap at n-1 so at least one token is computed (need logits)
            with eng.tracer.span("prefix.match", track=eng.name,
                                 request_id=req.request_id):
                self.m = eng.prefix_cache.match_and_ref(self.key_tokens,
                                                        cap=n_tokens - 1)
        else:
            self.m = MatchResult()
        if (mm_key is not None and mm_feats is None and not defer_features
                and self.m.n_tokens < req.mm_pos + self.n_mm):
            # the caller skipped the encode forward on the promise that
            # the cached prefix covers the whole image run; it must —
            # there are no features to scatter for the uncovered slice
            eng.pool.unref(self.m.page_ids)
            if self.m.cow_src is not None:
                eng.pool.unref([self.m.cow_src])
            raise ValueError(
                f"encode skipped but cached prefix covers only "
                f"{self.m.n_tokens} tokens of an image run ending at "
                f"{req.mm_pos + self.n_mm}")
        self.mm_args: tuple = ()
        if mm_feats is not None:
            self.mm_args = (jnp.asarray(mm_feats),
                            jnp.asarray(req.mm_pos, jnp.int32))
        self.n_shared = self.m.n_full_pages
        self.cow_held = self.m.cow_src is not None
        self.row = np.zeros((1, width), np.int32)
        self.row[0, :self.n_shared] = self.m.page_ids
        self.chunks: List[Tuple[int, int]] = []  # (computed tokens, pages)
        if self.n_shared:
            self.chunks.append((0, self.n_shared))  # ready before compute
        self.held: List[np.ndarray] = []        # fresh pages, for unwind
        self.logits = None
        self._new = None                        # last chunk's side caches
        self.done = self.m.n_tokens             # tokens already in the pool
        self.pos = self.n_shared * page         # page-aligned window start
        self.k = 0
        self.closed = False
        eng._inflight_tasks.append(self)

    @property
    def finished(self) -> bool:
        return self.pos >= self.n_tokens

    @property
    def next_chunk_tokens(self) -> int:
        """Tokens the next ``run_chunk`` would compute (0 once finished)."""
        return max(0, min(self.pos + self.C, self.n_tokens) - self.done)

    def planned_chunk_tokens(self) -> List[int]:
        """Computed-token split of the REMAINING chunks (deterministic
        from the window arithmetic) — what a cost model should charge
        per executed chunk."""
        out, done, pos = [], self.done, self.pos
        while pos < self.n_tokens:
            end = min(pos + self.C, self.n_tokens)
            out.append(end - done)
            done = end
            pos += -(-end // self.page) * self.page - pos
        return out

    def needs_features_next(self) -> bool:
        """Does the next chunk's window overlap the image run with no
        features supplied yet? True means the E->P feature-arrival
        barrier gates this chunk: ``supply_features`` must happen first.
        A cached prefix covering the whole run clears it for free."""
        if self.mm_key is None or self.mm_args or not self.n_mm:
            return False
        if self.done >= self.req.mm_pos + self.n_mm:
            return False
        return min(self.pos + self.C, self.n_tokens) > self.req.mm_pos

    def supply_features(self, mm_feats) -> None:
        """Land the Encode stage's features (the barrier dependency)."""
        self.mm_args = (jnp.asarray(mm_feats),
                        jnp.asarray(self.req.mm_pos, jnp.int32))

    def held_pages(self) -> List[int]:
        """Every pool page this in-flight task holds a ref on (for
        ``assert_balanced`` leak audits)."""
        out = [int(p) for p in self.m.page_ids]
        if self.cow_held:
            out.append(int(self.m.cow_src))
        for ids in self.held:
            out.extend(int(p) for p in ids)
        return out

    def run_chunk(self) -> int:
        """Advance one chunk window; returns the tokens computed.

        A :class:`PoolExhausted` from the page allocator propagates with
        the task state UNTOUCHED (nothing mutated yet this chunk) — the
        scheduler stalls the job and retries after decode frees pages.
        Any other failure must be unwound by the caller via ``abort``."""
        eng = self.eng
        page = self.page
        req = self.req
        if self.finished:
            raise ValueError("prefill task already finished")
        if self.needs_features_next():
            raise ValueError(
                f"request {req.request_id}: chunk {self.k} overlaps the "
                f"image run at {req.mm_pos} but no features were "
                f"supplied (feature-arrival barrier violated)")
        end = min(self.pos + self.C, self.n_tokens)
        with eng.tracer.span("prefill.chunk", track=eng.name,
                             request_id=req.request_id, chunk=self.k,
                             tokens=end - self.done):
            win = -(-end // page) * page - self.pos  # page-aligned window
            ids = eng._alloc_pages(-(-end // page) - self.pos // page)
            self.held.append(ids)
            if self.cow_held:
                # never write a shared page: private copy, then
                # overwrite its unmatched tail during the scatter
                eng.caches["attn"] = eng._cow_copy(
                    eng.caches["attn"],
                    jnp.asarray([self.m.cow_src], jnp.int32),
                    jnp.asarray([int(ids[0])], jnp.int32))
                eng.pool.unref([self.m.cow_src])
                self.cow_held = False
            self.row[0, self.pos // page:self.pos // page + len(ids)] = ids
            sfx = np.zeros((1, win), np.int32)
            sfx[0, self.done - self.pos:end - self.pos] = \
                self.feed_tokens[self.done:end]
            side = eng._side_caches()
            pcaches = {"attn": eng.caches["attn"],
                       "ssm": side["ssm"], "cross": side["cross"],
                       "len": side["len"], "pages": jnp.asarray(self.row)}
            # lengths = this chunk's end: positions past it are
            # dummies (masked scatter + position -1), so the window
            # never claims tokens a later chunk will compute
            self.logits, self._new = eng._prefill_suffix(
                eng.params, jnp.asarray(sfx),
                jnp.asarray([end], jnp.int32), pcaches,
                jnp.asarray(self.done, jnp.int32),
                jnp.asarray(self.pos, jnp.int32), *self.mm_args)
            eng.caches["attn"] = self._new["attn"]
        n = end - self.done
        self.chunks.append((n, len(ids)))
        self.done = end
        self.pos += win
        self.k += 1
        return n

    def finish(self):
        """Complete the prefill: first token from the last chunk's
        logits, radix retention, metrics — and every page ref moves to
        the returned ``(first_token, payload)``."""
        if self.closed:
            raise ValueError("prefill task already closed")
        if not self.finished:
            raise ValueError("prefill task still has chunks to run")
        eng = self.eng
        first = int(jnp.argmax(self.logits[0]))
        n_pages = self.n_shared + sum(len(ids) for ids in self.held)
        ids = np.asarray(self.row[0, :n_pages], np.int32)
        if eng.prefix_cache is not None:
            eng.prefix_cache.insert(self.key_tokens, ids)
        eng._count_prefill(self.n_tokens, self.n_tokens - self.m.n_tokens)
        payload = PagedKVPayload(
            source=eng, page_ids=ids, n_tokens=self.n_tokens,
            side={"ssm": self._new["ssm"], "cross": self._new["cross"],
                  "len": self._new["len"]},
            kv_nbytes=len(ids) * eng._attn_kv_nbytes(eng.caches["attn"]),
            cached_tokens=self.m.n_tokens,
            chunks=self.chunks if eng.chunked_prefill else [])
        self._close()
        return first, payload

    def abort(self) -> None:
        """Unwind every ref this task took (match, CoW source, every
        chunk's fresh pages) so an abandoned prefill leaks nothing."""
        if self.closed:
            return
        eng = self.eng
        eng.pool.unref(self.m.page_ids)
        if self.cow_held:
            eng.pool.unref([self.m.cow_src])
        for ids in self.held:
            eng.pool.unref(ids)
        self._close()

    def _close(self) -> None:
        self.closed = True
        if self in self.eng._inflight_tasks:
            self.eng._inflight_tasks.remove(self)


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 128, temperature: float = 0.0,
                 cache_dtype=jnp.float32, kv_dtype=None,
                 paged: bool = False, page_size: int = 16,
                 n_pool_pages: Optional[int] = None,
                 prefix_cache: bool = False,
                 chunked_prefill: bool = False, prefill_chunk: int = 32,
                 preemption: bool = False,
                 faults: Optional[FaultInjector] = None,
                 name: str = "engine",
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 accountant: Optional[LatencyAccountant] = None):
        self.cfg = cfg
        self.params = params
        # telemetry plane: span tracer (no-op unless enabled), shared
        # metrics registry (private one when standalone, so the counter
        # properties below always have a backing store), and the
        # cluster's latency accountant for swap-time reclassification.
        self.name = name
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.accountant = accountant
        self.max_batch = max_batch
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.kv_dtype = kv_dtype          # e.g. jnp.float8_e4m3fn (§Perf)
        self.paged = paged
        self.page_size = page_size
        self.chunked_prefill = chunked_prefill
        self.prefill_chunk = prefill_chunk
        if preemption and not paged:
            raise ValueError("preemption requires paged=True")
        self.preemption = preemption
        if chunked_prefill:
            if not paged:
                raise ValueError("chunked_prefill requires paged=True")
            if prefill_chunk <= 0 or prefill_chunk % page_size:
                raise ValueError(
                    f"prefill_chunk {prefill_chunk} must be a positive "
                    f"multiple of page {page_size}")
        self._decode = make_decode_fn(cfg, temperature)
        # encode-inline baseline for run_request: the SAME jitted
        # frontend-projector forward the Encode stage runs, so the
        # monolithic path is bit-identical to disaggregated E->P->D
        self._encode_inline = (make_encode_fn(cfg)
                               if cfg.frontend is not None
                               and cfg.encoder is None else None)
        if paged:
            if max_len % page_size:
                raise ValueError(
                    f"max_len {max_len} not a multiple of page {page_size}")
            per_slot = max_len // page_size
            if n_pool_pages is None:
                # all slots full + one in-flight prefill, + trash page 0
                n_pool_pages = 1 + (max_batch + 1) * per_slot
            self.pool = PagePool(n_pool_pages, page_size, injector=faults,
                                 metrics=self.metrics, name=name)
            self.caches = make_caches(
                cfg, max_batch, max_len, dtype=cache_dtype,
                kv_dtype=kv_dtype, layout="paged", page_size=page_size,
                n_pages=n_pool_pages)
            self._prefill = make_prefill_fn(cfg, donate_caches=True)
            self._insert_side = make_paged_insert_fn(cfg)
            self._copy_pages = make_page_copy_fn()
            self._gather_pages = make_page_gather_fn()
            self._scatter_pages = make_page_scatter_fn()
            self._slot_pages: List[Optional[np.ndarray]] = [None] * max_batch
        else:
            if prefix_cache:
                raise ValueError("prefix_cache requires paged=True")
            self._prefill = make_prefill_fn(cfg)
            self._insert = make_insert_fn(cfg)
            self.caches = make_caches(cfg, max_batch, max_len,
                                      dtype=cache_dtype, kv_dtype=kv_dtype)
        self.prefix_cache: Optional[PrefixCache] = None
        self._prefill_suffix = None
        if prefix_cache or chunked_prefill:
            if cfg.encoder is not None or cfg.ssm_layers:
                raise ValueError(
                    "prefix_cache/chunked_prefill need an attention-only "
                    "decoder: SSM state / cross-KV cannot be resumed "
                    "mid-sequence")
        # the suffix-prefill step serves the prefix-cache hit path AND
        # the recompute recovery arms (evicted-prefix re-fault, swap-loss
        # suffix recompute) — a preemption engine on an attention-only
        # decoder gets it even without a prefix cache, so a lost swap
        # handle is recoverable instead of fatal.
        if (prefix_cache or chunked_prefill
                or (preemption and cfg.encoder is None
                    and not cfg.ssm_layers)):
            self._prefill_suffix = make_prefill_fn(cfg, donate_caches=True,
                                                   prefix=True)
            self._cow_copy = make_pool_page_copy_fn()
        if prefix_cache:
            self.prefix_cache = PrefixCache(page_size, self.pool)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self._last_tok = np.zeros((max_batch,), np.int32)
        self._key = jax.random.PRNGKey(0)
        # Counters live in the metrics registry, labeled by engine name;
        # the historical attribute names (kv_insert_bytes_total,
        # refault_pages_total, ...) survive as read-through properties
        # below so existing tests/benchmarks read them unchanged.
        M = self.metrics
        # KV bytes moved by the most recent / all insert() calls — the
        # paged-vs-dense P->D handoff metric (benchmarks, acceptance).
        self._m_insert_bytes_last = M.gauge("kv_insert_bytes_last",
                                            engine=name)
        self._m_insert_bytes = M.counter("kv_insert_bytes_total",
                                         engine=name)
        # prefill work accounting: tokens the model actually computed vs
        # tokens requested — the prefix-cache savings metric.
        self._m_prefill_total = M.counter("prefill_tokens_total",
                                          engine=name)
        self._m_prefill_computed = M.counter("prefill_tokens_computed",
                                             engine=name)
        self._m_prefix_hit_rate = M.gauge("prefix_hit_rate", engine=name)
        # page-level preemption state: requests parked off-device, FIFO
        # resume order; marks record output length at resume for the
        # starvation guard (no second preemption before progress).
        self.preempted: List[PreemptedRequest] = []
        self._m_preempt = M.counter("preemptions_total", engine=name)
        self._m_resume = M.counter("resumes_total", engine=name)
        self._m_swap_out = M.counter("swap_out_pages_total", engine=name)
        self._m_swap_in = M.counter("swap_in_pages_total", engine=name)
        # prefix pages recomputed on resume
        self._m_refault = M.counter("refault_pages_total", engine=name)
        self._resume_marks: Dict[int, int] = {}
        # swap-loss recovery: resumes that had to recompute their private
        # pages because the host swap tier lost the handle, and requests
        # that could not be recovered (no suffix step / multimodal).
        self._m_swap_lost_rec = M.counter("swap_lost_recomputes_total",
                                          engine=name)
        self._m_lost = M.counter("lost_requests_total", engine=name)
        self.lost: List[Request] = []
        # a crashed instance is gone: serving calls raise InstanceDown
        # instead of silently running against a pool that no longer
        # exists. Set via mark_crashed() by the cluster's fault plane.
        self.crashed = False
        # swap/refault work done inside engine calls, to be reclassified
        # in the accountant's ledger by the cluster after its next
        # sync() (the time is already charged under the request's state;
        # note() moves it into the "swap" component, zero-sum).
        self._pending_notes: List[Tuple[int, str, float, str]] = []
        self._decode_steps = 0
        # iteration-level (continuous) batching: chunked prefills in
        # flight register here so leak audits see their page refs; the
        # scheduler is created lazily by the first submit(). The step
        # counters back the batching-smoke observability assertions.
        self._inflight_tasks: List[PrefillTask] = []
        self.scheduler: Optional[IterationScheduler] = None
        self._m_sched_steps = M.counter("sched_steps_total", engine=name)
        self._m_sched_chunks = M.counter("sched_chunks_total", engine=name)
        self._m_sched_admits = M.counter("sched_admissions_total",
                                         engine=name)
        self._m_sched_mixed = M.counter("sched_mixed_steps_total",
                                        engine=name)

    # -- telemetry back-compat properties ------------------------------------
    @property
    def kv_insert_bytes(self) -> int:
        return int(self._m_insert_bytes_last.value)

    @property
    def kv_insert_bytes_total(self) -> int:
        return int(self._m_insert_bytes.value)

    @property
    def prefill_tokens_total(self) -> int:
        return int(self._m_prefill_total.value)

    @property
    def prefill_tokens_computed(self) -> int:
        return int(self._m_prefill_computed.value)

    @property
    def preempt_count(self) -> int:
        return int(self._m_preempt.value)

    @property
    def resume_count(self) -> int:
        return int(self._m_resume.value)

    @property
    def swap_out_pages_total(self) -> int:
        return int(self._m_swap_out.value)

    @property
    def swap_in_pages_total(self) -> int:
        return int(self._m_swap_in.value)

    @property
    def refault_pages_total(self) -> int:
        return int(self._m_refault.value)

    @property
    def swap_lost_recomputes(self) -> int:
        return int(self._m_swap_lost_rec.value)

    def _count_prefill(self, n_total: int, n_computed: int) -> None:
        self._m_prefill_total.inc(n_total)
        self._m_prefill_computed.inc(n_computed)
        if self.prefix_cache is not None and self._m_prefill_total.value:
            self._m_prefix_hit_rate.set(
                1.0 - self._m_prefill_computed.value
                / self._m_prefill_total.value)

    def _note(self, request_id: int, component: str, dur: float,
              source: str) -> None:
        if self.accountant is not None and dur > 0:
            self._pending_notes.append((request_id, component, dur, source))

    def drain_notes(self) -> None:
        """Apply pending swap-time reclassifications to the accountant.
        The cluster calls this right after its wall-clock sync, so the
        source component has already been charged the interval the swap
        work happened in (note() is zero-sum and clamped)."""
        if self.accountant is not None:
            for rid, comp, amt, src in self._pending_notes:
                self.accountant.note(rid, comp, amt, src)
        self._pending_notes.clear()

    # -- capacity ------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.slots)

    @staticmethod
    def _attn_kv_nbytes(attn) -> int:
        """Attention-KV bytes per unit of axis 1 across all layers: one
        physical page for a paged pool (axis 1 = n_pages), one slot row
        for a dense batch-1 prefill cache (axis 1 = batch)."""
        n = 0
        for e in attn:
            if e is None:
                continue
            n += 2 * (e.k.size // e.k.shape[1]) * e.k.dtype.itemsize
        return int(n)

    # -- paged-pool helpers ---------------------------------------------------
    def _alloc_pages(self, n: int) -> np.ndarray:
        """Pool alloc with prefix-cache backpressure: on exhaustion, evict
        LRU tree retentions until the request fits, then retry. Raises
        :class:`PoolExhausted` when even eviction cannot cover it; it
        never preempts (resume paths use it, and a resume stealing pages
        from another active slot would be swap ping-pong)."""
        try:
            return self.pool.alloc(n)
        except PoolExhausted:
            if self.prefix_cache is None:
                raise
            self.prefix_cache.evict(n - self.pool.n_free)
            return self.pool.alloc(n)

    def _alloc_pages_preempting(self, n: int) -> np.ndarray:
        """Admission-path alloc: evict tree retentions first, then
        preempt active slots — lowest priority, fewest-pages-lost-first,
        never the last active slot — until the allocation fits. Raises
        :class:`PoolExhausted` when no eligible victim remains (deny
        instead of thrash)."""
        while True:
            try:
                return self._alloc_pages(n)
            except PoolExhausted:
                if not self.preemption or not self._preempt_one():
                    raise

    def _side_caches(self):
        return make_caches(self.cfg, 1, self.max_len, dtype=self.cache_dtype,
                           kv_dtype=self.kv_dtype, with_attn=False)

    def page_holders(self) -> List[Sequence[int]]:
        """Every holder of pool pages this engine knows about: one entry
        per active slot, the prefix-cache retentions, every in-flight
        chunked-prefill task, and finished-but-unadmitted continuous
        payloads (leak audits)."""
        holders: List[Sequence[int]] = [
            p for p in self._slot_pages if p is not None]
        if self.prefix_cache is not None:
            holders.append(self.prefix_cache.retained_pages())
        holders.extend(t.held_pages() for t in self._inflight_tasks)
        if self.scheduler is not None:
            holders.extend(job.result[1].page_ids
                           for job in self.scheduler.ready
                           if job.result is not None)
        return holders

    def assert_no_page_leaks(self, extra_holders: Sequence = ()) -> None:
        """Pool leak audit: every used page must be accounted for by an
        active slot, the radix tree, or a caller-supplied holder (e.g. an
        un-inserted payload), with exact per-page ref counts — and every
        host-swap entry by a preempted request's handle."""
        self.pool.assert_balanced(
            [*self.page_holders(), *extra_holders],
            swap_handles=[pr.handle for pr in self.preempted
                          if pr.handle is not None])

    # -- page-level preemption ------------------------------------------------
    def _preempt_one(self) -> bool:
        """Preempt one victim to relieve pool pressure. Returns False
        when nothing is eligible: fewer than two active slots (the last
        active request is never preempted — preempting it to serve
        itself or an incoming request is pure thrash), or every
        candidate is starvation-guarded."""
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if len(active) <= 1:
            return False
        cands = []
        for i in active:
            r = self.slots[i]
            pages = self._slot_pages[i]
            n_private = sum(1 for p in pages
                            if self.pool.refcount(int(p)) == 1)
            mark = self._resume_marks.get(r.request_id)
            cands.append(VictimCandidate(
                slot=i, pages_lost=n_private, priority=r.priority,
                made_progress=(mark is None
                               or len(r.output_tokens) > mark),
                preempt_count=r.n_preempts))
        v = pick_preemption_victim(cands)
        if v is None:
            return False
        self.preempt_slot(v.slot)
        return True

    def preempt_slot(self, slot: int) -> PreemptedRequest:
        """Evict one active decode slot to make room: tree-shared pages
        (the leading run with refcount > 1) are unref'd — their KV stays
        device-resident under the other holders' refs — and the private
        remainder (CoW copies, generated-token pages) is gathered to the
        host swap store. The request parks in ``self.preempted`` until
        ``try_resume`` re-admits it."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} is not active")
        pages = self._slot_pages[slot]
        t0 = time.perf_counter()
        n_shared = 0
        if self.prefix_cache is not None:
            while (n_shared < len(pages)
                   and self.pool.refcount(int(pages[n_shared])) > 1):
                n_shared += 1
        private = pages[n_shared:]
        handle = None
        with self.tracer.span("preempt.swap_out", track=self.name,
                              request_id=req.request_id,
                              n_private=len(private), n_shared=n_shared):
            if len(private):
                data = jax.device_get(self._gather_pages(
                    self.caches["attn"], jnp.asarray(private, jnp.int32)))
                handle = self.pool.swap_out(private, data)
                self._m_swap_out.inc(len(private))
            if n_shared:
                self.pool.unref(pages[:n_shared])

        def take(x):
            return np.asarray(x[:, slot:slot + 1])

        side = {"ssm": jax.tree.map(take, self.caches["ssm"]),
                "cross": (None if self.caches["cross"] is None else
                          jax.tree.map(take, self.caches["cross"])),
                "len": np.asarray(self.caches["len"][slot:slot + 1])}
        pr = PreemptedRequest(req=req, handle=handle,
                              n_shared_pages=n_shared, n_pages=len(pages),
                              side=side, last_tok=int(self._last_tok[slot]))
        self.slots[slot] = None
        self._slot_pages[slot] = None
        # unmap the row: the parked slot's lock-step decode writes land
        # on the trash page, never on re-allocated pages
        self.caches["pages"] = self.caches["pages"].at[slot].set(0)
        req.n_preempts += 1
        self._m_preempt.inc()
        if self.tracer.enabled:
            pr.t_parked = self.tracer.now()
        self._note(req.request_id, "swap", time.perf_counter() - t0,
                   source="compute")
        self.preempted.append(pr)
        return pr

    def try_resume(self) -> int:
        """Re-admit preempted requests in FIFO order while free slots
        and pages allow; stops at the first one that does not fit (FIFO
        keeps resume fair — no overtaking by smaller requests)."""
        n = 0
        while self.preempted and self.free_slots():
            if not self._resume(self.preempted[0], self.free_slots()[0]):
                break
            self.preempted.pop(0)
            n += 1
        return n

    def _resume(self, pr: PreemptedRequest, slot: int) -> bool:
        """Re-fault one preempted request into ``slot``: re-ref its
        shared prefix from the tree (recomputing any pages the tree
        evicted meanwhile into private copies), swap its private pages
        back in, and restore side state + block table. Returns False —
        with every ref unwound and the swap handle untouched — when the
        pool cannot cover it yet."""
        page = self.page_size
        row = np.zeros((self.max_len // page,), np.int32)
        n_shared = pr.n_shared_pages
        t0 = time.perf_counter()
        m = MatchResult()
        try:
            resident = 0
            if n_shared:
                m = self.prefix_cache.match_and_ref(
                    pr.req.prompt_tokens, cap=n_shared * page)
                if m.cow_src is not None:     # full pages only on resume
                    self.pool.unref([m.cow_src])
                    m.cow_src = None
                resident = m.n_full_pages
                row[:resident] = m.page_ids
            # reserve EVERYTHING still needed (evicted-prefix re-fault
            # pages + the swapped private set) in one atomic alloc, so a
            # failed attempt unwinds before any compute runs or the swap
            # handle is consumed — no repeated recompute, no double-
            # counted metrics across retries
            n_miss = n_shared - resident
            n_priv = pr.handle.n_pages if pr.handle is not None else 0
            ids_all = self._alloc_pages(n_miss + n_priv)
        except PoolExhausted:
            self.pool.unref(m.page_ids)
            return False
        if n_miss:
            # the tree evicted part of the shared prefix while this
            # request was parked: re-fault private copies by recomputing
            # those tokens' KV through the suffix step (prefix_len =
            # tokens still resident). Without this the block table would
            # dangle on freed/re-used pages.
            row[resident:n_shared] = ids_all[:n_miss]
            pos, end = resident * page, n_shared * page
            with self.tracer.span("preempt.refault", track=self.name,
                                  request_id=pr.req.request_id,
                                  n_pages=n_miss):
                sfx = np.asarray(pr.req.prompt_tokens[pos:end],
                                 np.int32)[None]
                side = self._side_caches()
                pcaches = {"attn": self.caches["attn"], "ssm": side["ssm"],
                           "cross": side["cross"], "len": side["len"],
                           "pages": jnp.asarray(row[None])}
                _, new = self._prefill_suffix(
                    self.params, jnp.asarray(sfx),
                    jnp.asarray([end], jnp.int32), pcaches,
                    jnp.asarray(pos, jnp.int32), jnp.asarray(pos, jnp.int32))
                self.caches["attn"] = new["attn"]
            self._m_refault.inc(n_miss)
        if pr.handle is not None:
            # hand the reserved pages back so swap_in (the only consumer
            # of the handle) re-pops exactly them — it cannot fail now
            # on pool pressure (it CAN still lose the handle's contents
            # when the swap-tier fault site fires, see below)
            self.pool.free(ids_all[n_miss:])
            try:
                ids, data = self.pool.swap_in(pr.handle)
            except SwapLost:
                return self._recover_swap_lost(pr, slot, row, n_shared, t0)
            with self.tracer.span("preempt.swap_in", track=self.name,
                                  request_id=pr.req.request_id,
                                  n_pages=len(ids)):
                row[n_shared:n_shared + len(ids)] = ids
                self.caches["attn"] = self._scatter_pages(
                    self.caches["attn"], data, jnp.asarray(ids))
            self._m_swap_in.inc(len(ids))
        self.caches = self._insert_side(pr.side, self.caches,
                                        jnp.asarray(row), slot)
        self._slot_pages[slot] = np.asarray(row[:pr.n_pages], np.int32)
        self.slots[slot] = pr.req
        self._last_tok[slot] = pr.last_tok
        self._resume_marks[pr.req.request_id] = len(pr.req.output_tokens)
        self._m_resume.inc()
        self._mark_resumed(pr, t0)
        return True

    def _mark_resumed(self, pr: PreemptedRequest, t0: float) -> None:
        """Shared resume bookkeeping: the parked gap becomes a span on
        this engine's track, and the re-fault work done inside this call
        is reclassified from the request's parked-queue time into its
        swap component."""
        if self.tracer.enabled:
            self.tracer.add("preempt.parked", pr.t_parked, self.tracer.now(),
                            track=self.name, request_id=pr.req.request_id,
                            n_pages=pr.n_pages)
        self._note(pr.req.request_id, "swap", time.perf_counter() - t0,
                   source="queue")

    def _recover_swap_lost(self, pr: PreemptedRequest, slot: int,
                           row: np.ndarray, n_shared: int,
                           t0: float) -> bool:
        """Swap-loss recovery arm: the host swap tier lost the handle's
        contents mid-``_resume`` (the handle is consumed — there is
        nothing left to retry against). The KV it held is nonetheless
        reconstructible: at preemption the cache covered
        ``prompt + output_tokens[:-1]`` (the final output token is
        ``last_tok``, still waiting to be fed), and greedy decode is
        deterministic — so recomputing exactly those token positions
        through the suffix-prefill step rebuilds bit-identical KV in
        fresh private pages, and decode resumes at the exact position.

        Engines without the suffix step (SSM / cross-attention decoders)
        or multimodal requests (their feature embeddings are not
        retained) cannot recompute: the request is killed, every page
        ref unwound, and the loss surfaced via ``self.lost`` — never a
        silent drop. Always returns True: the preempted entry is
        consumed either way (the handle no longer exists)."""
        req = pr.req
        page = self.page_size
        n_priv = pr.n_pages - n_shared
        if self._prefill_suffix is None or req.is_multimodal:
            if n_shared:
                self.pool.unref(row[:n_shared])
            req.killed = True
            self.lost.append(req)
            self._m_lost.inc()
            return True
        # the reservation freed just before swap_in is still on the free
        # list — reclaim it for the recomputed copies
        with self.tracer.span("recover.swap_lost", track=self.name,
                              request_id=req.request_id, n_pages=n_priv):
            ids = self._alloc_pages(n_priv)
            row[n_shared:n_shared + n_priv] = ids
            seq = list(req.prompt_tokens) + list(req.output_tokens[:-1])
            pos = n_shared * page
            win = n_priv * page
            sfx = np.zeros((1, win), np.int32)
            sfx[0, :len(seq) - pos] = seq[pos:]
            side = self._side_caches()
            pcaches = {"attn": self.caches["attn"], "ssm": side["ssm"],
                       "cross": side["cross"], "len": side["len"],
                       "pages": jnp.asarray(row[None])}
            _, new = self._prefill_suffix(
                self.params, jnp.asarray(sfx),
                jnp.asarray([len(seq)], jnp.int32), pcaches,
                jnp.asarray(pos, jnp.int32), jnp.asarray(pos, jnp.int32))
            self.caches["attn"] = new["attn"]
        self._m_swap_lost_rec.inc()
        self._m_refault.inc(n_priv)
        self.caches = self._insert_side(pr.side, self.caches,
                                        jnp.asarray(row), slot)
        self._slot_pages[slot] = np.asarray(row[:pr.n_pages], np.int32)
        self.slots[slot] = req
        self._last_tok[slot] = pr.last_tok
        self._resume_marks[req.request_id] = len(req.output_tokens)
        self._m_resume.inc()
        self._mark_resumed(pr, t0)
        return True

    # -- stages --------------------------------------------------------------
    def prefill_request(self, req: Request, mm_embeds=None,
                        enc_frames=None, mm_feats=None, mm_key=None):
        """Run Prefill for one request (batch=1). Returns (first_token,
        payload) — the payload is the P->D handoff unit: the prefilled
        cache pytree (dense) or a PagedKVPayload naming pool pages.

        With the prefix cache enabled, text-only prompts reuse the
        longest cached prefix and compute only the suffix.

        Multimodal inputs arrive one of two ways:
        * ``mm_embeds`` — RAW frontend embeddings, projected and
          prepended inside the forward (the legacy fused path; falls
          back to monolithic prefill).
        * ``mm_feats`` + ``mm_key`` — the Encode-stage hand-off:
          features ALREADY projected to d_model (from the MM Store),
          scattered into the embedding stream at image-token positions
          [req.mm_pos, req.mm_pos + n_mm). ``mm_key`` (the content
          hash) extends the radix prefix-cache key with a pseudo-token
          run, so identical image+prompt pairs compose MM Store dedup
          with KV reuse — and composes with chunked prefill: text
          chunks proceed normally, the chunk overlapping the image run
          scatters exactly its slice. ``mm_feats=None`` with ``mm_key``
          set means the caller skipped the encode forward because the
          prefix cache covers the whole image run (verified here).
        """
        with self.tracer.span("prefill", track=self.name,
                              request_id=req.request_id,
                              tokens=len(req.prompt_tokens)):
            return self._prefill_request(req, mm_embeds, enc_frames,
                                         mm_feats, mm_key)

    def _prefill_request(self, req: Request, mm_embeds=None,
                         enc_frames=None, mm_feats=None, mm_key=None):
        cfg = self.cfg
        n_mm = 0
        if mm_feats is not None:
            n_mm = mm_feats.shape[1]
        elif mm_key is not None:
            n_mm = req.mm_tokens
        elif mm_embeds is not None and cfg.encoder is None:
            n_mm = mm_embeds.shape[1]
        toks = np.asarray(req.prompt_tokens, np.int32)[None]
        pad = self.max_len - n_mm - toks.shape[1]
        if pad < 0:
            raise ValueError(
                f"prompt ({toks.shape[1]}+{n_mm}) exceeds max_len {self.max_len}")
        n_tokens = len(req.prompt_tokens) + n_mm

        scatter = mm_feats is not None or mm_key is not None
        if ((self.chunked_prefill or self.prefix_cache is not None)
                and mm_embeds is None and enc_frames is None
                and (n_mm == 0 or scatter) and self.paged):
            return self._prefill_chunked(req, n_tokens, mm_feats, mm_key)
        if mm_key is not None and mm_feats is None:
            raise ValueError(
                "encode was skipped (mm_feats=None) but this engine has "
                "no prefix cache to supply the image run's KV")

        mm_start = None
        if scatter:
            # feed placeholder 0-tokens at image positions; the scatter
            # overwrites their embeddings with the projected features
            p = list(req.prompt_tokens)
            toks = np.asarray(p[:req.mm_pos] + [0] * n_mm + p[req.mm_pos:],
                              np.int32)[None]
            mm_start = jnp.asarray(req.mm_pos, jnp.int32)
        # pad the TEXT width: a scatter-path toks already contains the
        # n_mm placeholders, a prepend-path toks grows them inside the
        # forward — either way the model sees max_len positions.
        lengths = jnp.asarray([n_tokens], jnp.int32)
        if not self.paged:
            toks = np.pad(toks, ((0, 0), (0, pad)))
            caches = make_caches(cfg, 1, self.max_len, dtype=self.cache_dtype,
                                 kv_dtype=self.kv_dtype)
            logits, caches = self._prefill(self.params, jnp.asarray(toks),
                                           lengths, caches, mm_embeds,
                                           enc_frames, mm_feats, mm_start)
            first = int(jnp.argmax(logits[0]))
            self._count_prefill(n_tokens, n_tokens)
            return first, caches

        # ---- paged: write KV straight into this engine's pool pages ----
        toks = np.pad(toks, ((0, 0), (0, pad)))
        ids = self._alloc_pages(self.pool.pages_for(n_tokens))
        row = np.zeros((1, self.max_len // self.page_size), np.int32)
        row[0, :len(ids)] = ids
        side = self._side_caches()
        pcaches = {"attn": self.caches["attn"], "ssm": side["ssm"],
                   "cross": side["cross"], "len": side["len"],
                   "pages": jnp.asarray(row)}
        logits, new = self._prefill(self.params, jnp.asarray(toks), lengths,
                                    pcaches, mm_embeds, enc_frames,
                                    mm_feats, mm_start)
        self.caches["attn"] = new["attn"]      # pool pages updated in place
        first = int(jnp.argmax(logits[0]))
        self._count_prefill(n_tokens, n_tokens)
        payload = PagedKVPayload(
            source=self, page_ids=ids, n_tokens=n_tokens,
            side={"ssm": new["ssm"], "cross": new["cross"],
                  "len": new["len"]},
            kv_nbytes=len(ids) * self._attn_kv_nbytes(self.caches["attn"]))
        return first, payload

    def _prefill_chunked(self, req: Request, n_tokens: int,
                         mm_feats=None, mm_key=None):
        """Chunked prefill (text-only, batch 1): compute the prompt in
        fixed windows of ``prefill_chunk`` tokens. Chunk *k* allocates
        only its own pages, scatters its KV into the pool, and attends
        over chunks 0..k-1 via the block-table gather (``prefix_len`` =
        tokens already resident, ``pos_base`` = the chunk's page-aligned
        start) — so the in-flight window is O(chunk), not O(prompt).

        With the prefix cache enabled, the longest cached prefix is
        ref'd first and whole leading chunks are skipped; a match ending
        inside a page is copied on write so shared pages are never
        mutated. The payload records per-chunk (tokens, pages) segments
        so the P->D planner can stream chunk *k* while chunk *k+1*
        computes.

        This is ALSO the prefix-cache hit path of a non-chunked engine:
        with the window widened to the whole prompt, the loop runs once
        and degenerates to the monolithic suffix prefill (same trace
        bucket, same CoW/unwind protocol — one implementation to audit).
        Such payloads carry no segments, so the cluster plans their
        transfer monolithically.

        Implementation: a :class:`PrefillTask` driven to completion in
        a tight loop — the SAME state machine the iteration-level
        scheduler advances one chunk at a time, so the serial and
        continuous paths share one implementation to audit and are
        bit-identical by construction."""
        task = PrefillTask(self, req, n_tokens, mm_feats, mm_key)
        try:
            while not task.finished:
                task.run_chunk()
        except BaseException:
            # un-wind every ref this request took (match, CoW source,
            # every chunk's fresh pages) so a failed prefill leaks nothing
            task.abort()
            raise
        return task.finish()

    def insert(self, req: Request, prefilled, first_token: int,
               append_token: bool = True) -> int:
        """Attach a prefilled request to a free decode slot (P->D import).

        Dense: copy the batch-1 cache into batch slot ``slot``.
        Paged: adopt the payload's pages — a block-table write when the
        pages are already in this engine's pool, else an O(pages) copy.
        A failed paged insert (no free slot, destination pool full)
        raises before mutating anything: the payload stays retryable.
        Abandon one with ``release_payload`` or its pages leak.

        ``append_token=False`` skips recording ``first_token`` as a new
        output: a re-route/migration insert resumes a request whose
        ``output_tokens`` already contain it (the token is only the next
        decode input, not new progress).
        """
        if self.crashed:
            raise InstanceDown(self.name, 0)
        free = self.free_slots()
        if not free:
            raise NoFreeSlot()
        slot = free[0]
        with self.tracer.span("insert", track=self.name,
                              request_id=req.request_id):
            if self.paged:
                self._insert_paged(prefilled, slot)
            else:
                self.caches = self._insert(prefilled, self.caches, slot)
                nbytes = self._attn_kv_nbytes(prefilled["attn"])
                self._m_insert_bytes_last.set(nbytes)
                self._m_insert_bytes.inc(nbytes)
        self.slots[slot] = req
        self._last_tok[slot] = first_token
        if append_token:
            req.output_tokens.append(first_token)
        return slot

    def release_payload(self, payload: PagedKVPayload) -> None:
        """Drop an un-inserted paged payload, returning its pages to the
        source pool. A failed ``insert`` (no free slot / destination
        pool exhausted) leaves the payload intact and retryable; call
        this when abandoning it instead, or the pages leak until the
        source engine is rebuilt."""
        if len(payload.page_ids):
            payload.source.pool.free(payload.page_ids)
            payload.page_ids = np.zeros((0,), np.int32)

    def _insert_paged(self, payload: PagedKVPayload, slot: int) -> None:
        if payload.source is self:
            ids = payload.page_ids               # zero-copy handoff
            self._m_insert_bytes_last.set(0)
        else:
            ids = self._alloc_pages_preempting(payload.n_pages)
            self.caches["attn"] = self._copy_pages(
                payload.source.caches["attn"], self.caches["attn"],
                jnp.asarray(payload.page_ids), jnp.asarray(ids))
            payload.source.pool.free(payload.page_ids)
            self._m_insert_bytes_last.set(payload.kv_nbytes)
        self._m_insert_bytes.inc(self._m_insert_bytes_last.value)
        row = np.zeros((self.max_len // self.page_size,), np.int32)
        row[:len(ids)] = ids
        self.caches = self._insert_side(payload.side, self.caches,
                                        jnp.asarray(row), slot)
        self._slot_pages[slot] = np.asarray(ids)
        # neutralize the payload: its refs now belong to the slot, so a
        # stray release_payload must be a no-op, not an unref of pages a
        # live slot (or the prefix tree) still owns
        payload.page_ids = np.zeros((0,), np.int32)

    def _grow_pages(self, lens: np.ndarray) -> None:
        """Map a fresh page for any slot whose next token crosses a page
        boundary (host-side allocator; one batched table update).

        The allocation is all-or-nothing: every slot's demand is summed
        and allocated in one pool call BEFORE any bookkeeping mutates,
        so a pool-exhaustion error leaves host state and device block
        tables consistent (the caller can drain slots and retry).

        With ``preemption=True``, exhaustion preempts a victim (fewest
        private pages lost, never the last active slot) and re-derives
        the demand — a preempted slot both frees pages and drops out of
        the demand list — repeating until the growth fits or no victim
        remains (then the typed :class:`PoolExhausted` propagates,
        which is the pre-preemption kill behavior)."""
        width = self.max_len // self.page_size
        while True:
            demand: List[Tuple[int, int, int]] = []    # (slot, have, n_new)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                need = min(int(lens[i]) // self.page_size + 1, width)
                have = len(self._slot_pages[i])
                if need > have:
                    demand.append((i, have, need - have))
            if not demand:
                return
            try:
                ids = self._alloc_pages(sum(n for _, _, n in demand))
                break                                  # atomic
            except PoolExhausted:
                if not self.preemption or not self._preempt_one():
                    raise
        updates: List[Tuple[int, int, int]] = []
        off = 0
        for i, have, n in demand:
            new = ids[off:off + n]
            off += n
            self._slot_pages[i] = np.concatenate([self._slot_pages[i], new])
            updates.extend((i, have + j, int(p)) for j, p in enumerate(new))
        rows, cols, vals = zip(*updates)
        self.caches["pages"] = self.caches["pages"].at[
            list(rows), list(cols)].set(jnp.asarray(vals, jnp.int32))

    def _release_slot(self, slot: int) -> None:
        if self._slot_pages[slot] is not None:
            self.pool.free(self._slot_pages[slot])
            self._slot_pages[slot] = None
        # unmap the row so stale entries can't alias re-allocated pages;
        # a freed slot's decode writes land on the trash page.
        self.caches["pages"] = self.caches["pages"].at[slot].set(0)

    def mark_crashed(self) -> List[Request]:
        """The fault plane declared this instance dead: harvest every
        request it owned — active slots plus parked preemptees — for the
        cluster's re-route arm, and flip ``crashed`` so later serving
        calls raise :class:`InstanceDown` instead of quietly computing
        against a pool that no longer exists. Slot/pool state is NOT
        unwound (the device is gone, there is nothing to free into);
        leak audits exclude crashed instances."""
        self.crashed = True
        out = [r for r in self.slots if r is not None]
        out += [pr.req for pr in self.preempted]
        return out

    def decode_step(self) -> List[Tuple[Request, int, bool]]:
        """One lock-step decode over all slots. Returns (req, token, done)
        for every ACTIVE slot (inactive slots compute but are ignored).
        Preempted requests are re-admitted first (FIFO, page-permitting)
        so a resumed slot decodes in this very step.

        Decode spans are SAMPLED: one ``decode.step`` span every
        ``tracer.decode_sample`` steps (this is the highest-frequency
        phase; per-step spans at production rates would dominate the
        trace)."""
        if self.crashed:
            raise InstanceDown(self.name, 0)
        self._decode_steps += 1
        if self.tracer.want_decode_span(self._decode_steps):
            with self.tracer.span("decode.step", track=self.name,
                                  step=self._decode_steps,
                                  batch=self.n_active):
                return self._decode_step_inner()
        return self._decode_step_inner()

    def _decode_step_inner(self) -> List[Tuple[Request, int, bool]]:
        if self.paged and self.preempted:
            self.try_resume()
        if self.n_active == 0:
            # idle-batch early-out: with zero active slots the jitted
            # forward would compute only trash-page rows — skip the
            # dispatch AND the device->host len sync entirely. (Checked
            # after try_resume so a successful re-admission still
            # decodes this very step.)
            return []
        # single device->host sync per step (not per slot)
        lens = np.asarray(self.caches["len"])
        if self.paged:
            self._grow_pages(lens)
        self._key, sub = jax.random.split(self._key)
        toks, self.caches = self._decode(
            self.params, jnp.asarray(self._last_tok), self.caches, sub)
        toks = np.asarray(toks)
        out = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            t = int(toks[i])
            self._last_tok[i] = t
            req.output_tokens.append(t)
            # lens[i] is the PRE-step resident length: this step's KV
            # landed at index lens[i], so the cache now holds lens[i]+1
            # tokens and the next step would write at lens[i]+1 — done
            # exactly when that would spill past max_len (the cache can
            # fill to the last position, no give-away row).
            done = (t == req.eos_token or
                    len(req.output_tokens) >= req.max_new_tokens or
                    int(lens[i]) + 1 >= self.max_len)
            if done:
                self.slots[i] = None
                self._resume_marks.pop(req.request_id, None)
                if self.paged:
                    self._release_slot(i)
            out.append((req, t, done))
        return out

    # -- continuous batching (iteration-level scheduling, fused PD) -----------
    def start_prefill_task(self, req: Request, mm_feats=None, mm_key=None,
                           defer_features: bool = False) -> PrefillTask:
        """Create (without running) the resumable chunk state machine
        for one request's prefill — the unit the iteration scheduler
        advances. Requires the paged suffix-prefill path; multimodal
        only via the scatter hand-off (``mm_feats``/``mm_key``)."""
        if not self.paged or self._prefill_suffix is None:
            raise ValueError(
                "continuous batching needs a paged engine with the "
                "suffix-prefill step (chunked_prefill / prefix_cache on "
                "an attention-only decoder)")
        n_mm = 0
        if mm_feats is not None:
            n_mm = mm_feats.shape[1]
        elif mm_key is not None:
            n_mm = req.mm_tokens
        n_tokens = len(req.prompt_tokens) + n_mm
        if n_tokens > self.max_len:
            raise ValueError(
                f"prompt ({n_tokens}) exceeds max_len {self.max_len}")
        return PrefillTask(self, req, n_tokens, mm_feats, mm_key,
                           defer_features=defer_features)

    def submit(self, req: Request, *, mm_feats=None, mm_key=None,
               ready_at: float = 0.0,
               feature_ready_at: float = 0.0) -> PrefillJob:
        """Queue one request for continuous (iteration-level) serving on
        this fused engine; ``step()`` drains the queue. The scheduler is
        created on first use — engines never pay for it otherwise."""
        if self.scheduler is None:
            self.scheduler = IterationScheduler()
        n_mm = mm_feats.shape[1] if mm_feats is not None else (
            req.mm_tokens if mm_key is not None else 0)
        job = PrefillJob(
            req=req, n_tokens=len(req.prompt_tokens) + n_mm,
            chunk=self.prefill_chunk if self.chunked_prefill
            else self.max_len,
            ready_at=ready_at, feature_ready_at=feature_ready_at)
        job.meta["mm_feats"] = mm_feats
        job.meta["mm_key"] = mm_key
        return self.scheduler.submit(job)

    def step(self, now: float = 0.0) -> List[Tuple[Request, int, bool]]:
        """One continuous-batching iteration: execute the scheduler's
        batch plan — admit finished prefills into free decode slots,
        advance one chunk of each scheduled prefill, then run one
        lock-step decode over every active slot. Returns the decode
        outputs (same shape as ``decode_step``)."""
        sched = self.scheduler
        if sched is None:
            return (self.decode_step()
                    if self.n_active or self.preempted else [])
        plan = sched.plan(now=now, free_slots=len(self.free_slots()),
                          active_decode=self.n_active
                          + len(self.preempted))
        return self.execute_plan(plan)

    def execute_plan(self, plan: BatchPlan) -> List[Tuple[Request, int, bool]]:
        """Carry out one batch plan against this fused engine. Split
        from ``step`` so tests can drive hand-built plans."""
        sched = self.scheduler
        self._m_sched_steps.inc()
        with self.tracer.span("sched.step", track=self.name,
                              step=plan.step, n_chunks=len(plan.chunks),
                              n_admit=len(plan.admit),
                              batch=self.n_active):
            for job in plan.admit:
                first, payload = job.result
                try:
                    self.insert(job.req, payload, first)
                except (NoFreeSlot, PoolExhausted):
                    sched.requeue_ready(job)
                    continue
                self._m_sched_admits.inc()
            for job in plan.chunks:
                if job.task is None:
                    job.task = self.start_prefill_task(
                        job.req, job.meta.get("mm_feats"),
                        job.meta.get("mm_key"),
                        defer_features=job.feature_ready_at > 0)
                try:
                    job.task.run_chunk()
                except PoolExhausted:
                    # allocator left the task untouched: stall + retry
                    # once decode drain / preemption frees pages
                    sched.note_stall(job, "pool")
                    continue
                self._m_sched_chunks.inc()
                if job.task.finished:
                    job.result = job.task.finish()
                    sched.mark_ready(job)
            out = []
            if plan.decode and (self.n_active or self.preempted):
                if plan.chunks:
                    self._m_sched_mixed.inc()
                out = self.decode_step()
        return out

    def drain_continuous(self, max_steps: int = 10_000,
                         now_fn=None) -> List[Tuple[Request, int, bool]]:
        """Step until every submitted request has prefetched, admitted,
        and decoded to completion. ``now_fn`` supplies the modeled clock
        for barrier checks (default: barriers already satisfied)."""
        out: List[Tuple[Request, int, bool]] = []
        steps = 0
        while ((self.scheduler is not None and self.scheduler.has_work)
               or self.n_active or self.preempted):
            out.extend(self.step(now=now_fn() if now_fn else 0.0))
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"continuous drain made no progress in {max_steps} "
                    f"steps (stalls: "
                    f"{self.scheduler.stall_counts if self.scheduler else {}})")
        return out

    # -- monolithic convenience (the vLLM-style baseline) ---------------------
    def run_request(self, req: Request) -> List[int]:
        """Serial E->P->D for one request on this single engine. VLM
        requests run encode-inline-with-prefill: the frontend forward
        happens here, serialized before prefill, through the same jitted
        projector the Encode stage uses — so greedy outputs match the
        disaggregated path bit-for-bit."""
        mm = None
        enc = None
        mm_feats = None
        mm_key = None
        cfg = self.cfg
        if req.is_multimodal and cfg.frontend is not None:
            feats = FE.stub_embeddings(cfg, req.mm_payload,
                                       req.mm_tokens or None)
            if cfg.encoder is not None:
                enc = feats[None]
            else:
                mm_key = FE.content_hash(req.mm_payload)
                mm_feats = np.asarray(
                    self._encode_inline(self.params, feats))[None]
        first, caches = self.prefill_request(req, mm, enc, mm_feats, mm_key)
        self.insert(req, caches, first)
        while any(s is req for s in self.slots):
            self.decode_step()
        return req.output_tokens

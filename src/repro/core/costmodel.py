"""Roofline-derived stage cost model.

Maps (model config, stage, request shape, instance resources) to service
times, using the same three-term decomposition as the dry-run roofline
(EXPERIMENTS.md §Roofline): compute = FLOPs / (chips * peak), memory =
bytes / (chips * HBM bw), collective = bytes / link bw. A stage's service
time is max(compute, memory) + collective + fixed launch overhead.

Hardware constants are the TPU v5e target (the paper's Ascend Atlas 800I
A2 is comparable per-chip; DESIGN.md records the swap). Efficiencies are
de-rates from peak, the usual 0.4-0.6 MFU band for prefill-like work.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # B/s per chip
    link_bw: float = 50e9             # B/s per ICI link (paper: RDMA/HCCS)
    # E->P features go through the MM Store (DRAM staging + network, the
    # Mooncake path): effective bandwidth back-computed from the paper's
    # Table 3 measurements (0.72 MB in 8.1 ms ... 116 MB in 730 ms).
    store_bw: float = 0.16e9
    mfu: float = 0.5                  # achievable fraction of peak, compute
    mbu: float = 0.7                  # achievable fraction of HBM bw
    tp_allreduce_lat: float = 8e-6    # per-collective latency, seconds
    tp_efficiency: float = 0.7        # per-doubling compute scaling under TP
    launch_overhead: float = 2e-4     # per-step host/launch overhead
    handshake: float = 2e-3           # KV-transfer metadata handshake (paper §3.3)
    # device<->host staging link for page-level preemption swaps (PCIe
    # 4.0 x16 class) + the per-swap fixed cost (descriptor build, pinned
    # staging-buffer handoff, allocator round trip)
    host_bw: float = 25.6e9
    swap_latency: float = 0.3e-3
    # cross-instance dispatch overhead (scheduler tick, batch formation,
    # local cache write) — the "scheduling latency" of the paper's Table 3:
    # ~30 ms base plus a store-bandwidth write of the feature.
    dispatch_base: float = 30e-3
    dtype_bytes: int = 2


V5E = Hardware()
# cross-node disaggregation profile: KV moves over RDMA/DCN instead of ICI
RDMA = Hardware(link_bw=12.5e9, handshake=13e-3)


# ViT encoder proxy for the Encode stage (paper: 0.6-6B ViT params).
@dataclass(frozen=True)
class EncoderModel:
    params: float = 0.7e9             # openPangu-7B-VL ViT
    d_model: int = 1280
    n_layers: int = 32
    # ViT runs on pre-merge patches (2x2 pixel-unshuffle before the
    # projector is standard in Qwen2-VL-style stacks) — internal sequence
    # is ~4x the emitted vision tokens. This is what makes Encode rival
    # Prefill in latency (paper Fig. 2).
    internal_multiplier: int = 4


@dataclass(frozen=True)
class CostModel:
    cfg: ModelConfig
    hw: Hardware = V5E
    vit: EncoderModel = EncoderModel()
    # paged KV: tokens per pool page (0 = dense layout). P->D payloads
    # round up to whole pages and transfers plan at page granularity.
    page_tokens: int = 0

    # ---- stage compute ------------------------------------------------------
    def _chip_rate(self, chips: int, tp: int) -> float:
        """Aggregate compute rate: TP scales sub-linearly (sync overhead)."""
        eff = self.hw.tp_efficiency ** max(0, (tp - 1).bit_length()) \
            if tp > 1 else 1.0
        return chips * self.hw.peak_flops * self.hw.mfu * eff

    def encode_time(self, n_tokens: int, chips: int = 1, tp: int = 1) -> float:
        """ViT forward over n visual tokens (compute-bound)."""
        n_int = n_tokens * self.vit.internal_multiplier
        flops = 2.0 * self.vit.params * n_int
        # quadratic attention term
        flops += 4.0 * self.vit.n_layers * n_int ** 2 * self.vit.d_model
        t = flops / self._chip_rate(chips, tp)
        return t + self.hw.launch_overhead + self._tp_penalty(tp, self.vit.n_layers)

    def prefill_time(self, prompt_len: int, chips: int = 1, tp: int = 1,
                     cached_prefix: float = 0.0) -> float:
        """One request's prefill. ``cached_prefix`` tokens are served from
        the prefix cache: linear (MLP/projection) FLOPs cover only the
        computed suffix, and the quadratic term is suffix queries against
        the FULL context (cached KV is still attended to)."""
        cfg = self.cfg
        n_active = cfg.active_param_count()
        computed = max(1.0, prompt_len - max(0.0, cached_prefix))
        flops = 2.0 * n_active * computed
        attn_layers = len(cfg.attn_layers) or 0
        if attn_layers:
            eff_ctx = prompt_len if cfg.sliding_window is None else min(
                prompt_len, cfg.sliding_window)
            flops += 4.0 * attn_layers * computed * eff_ctx * cfg.q_dim
        t_c = flops / self._chip_rate(chips, tp)
        t_m = self.param_bytes() / (chips * self.hw.hbm_bw * self.hw.mbu)
        t = max(t_c, t_m)
        return t + self.hw.launch_overhead + self._tp_penalty(tp, cfg.n_layers)

    def chunk_prefill_times(self, prompt_len: int,
                            chunk_tokens: "list[float]", chips: int = 1,
                            tp: int = 1,
                            cached_prefix: float = 0.0) -> "list[float]":
        """Per-chunk slices of one request's prefill for the chunked
        streaming schedule (kv_transfer.plan_chunked).

        ``chunk_tokens[k]`` is the number of tokens chunk *k* computes
        (a leading 0 entry models a cached-prefix segment: no compute,
        its KV is already resident). The monolithic
        ``prefill_time(prompt_len, cached_prefix=...)`` is split across
        chunks proportional to each chunk's FLOPs — linear terms on its
        computed tokens, the quadratic attention term against its
        end-of-chunk context — so chunking never changes total modeled
        compute; each chunk past the first adds one ``launch_overhead``
        (the extra kernel dispatch), which is the honest cost of
        chunking that the transfer overlap has to beat.
        """
        cfg = self.cfg
        total = self.prefill_time(prompt_len, chips, tp,
                                  cached_prefix=cached_prefix)
        n_active = cfg.active_param_count()
        attn_layers = len(cfg.attn_layers)
        ctx = max(0.0, cached_prefix)
        weights = []
        for c in chunk_tokens:
            ctx += c
            w = 2.0 * n_active * c
            if attn_layers and c:
                eff_ctx = ctx if cfg.sliding_window is None else min(
                    ctx, cfg.sliding_window)
                w += 4.0 * attn_layers * c * eff_ctx * cfg.q_dim
            weights.append(w)
        wsum = sum(weights) or 1.0
        out = [total * w / wsum for w in weights]
        extra = 0
        for k, c in enumerate(chunk_tokens):
            if c <= 0:
                continue
            if extra:
                out[k] += self.hw.launch_overhead
            extra += 1
        return out

    def decode_step_time(self, batch: int, kv_len: float, chips: int = 1,
                         tp: int = 1) -> float:
        """One decode iteration for a batch (memory-bound)."""
        cfg = self.cfg
        bytes_moved = self.param_bytes() + batch * self.kv_bytes_per_token() \
            * self._eff_kv(kv_len)
        t_m = bytes_moved / (chips * self.hw.hbm_bw * self.hw.mbu)
        flops = 2.0 * cfg.active_param_count() * batch
        t_c = flops / self._chip_rate(chips, tp)
        t = max(t_m, t_c)
        return t + self.hw.launch_overhead + self._tp_penalty(tp, cfg.n_layers)

    def swap_time(self, n_pages: int) -> float:
        """One-direction host-link time to move ``n_pages`` of KV between
        the device pool and host memory: the service-time cost of a
        page-level preemption swap-out, or of the swap-in at re-fault.
        The simulator charges it into the decode stream (the honest
        pessimistic placement: the pool pages are not reusable until the
        copy lands)."""
        if n_pages <= 0:
            return 0.0
        if not self.page_tokens:
            raise ValueError("swap_time needs a paged layout "
                             "(page_tokens > 0)")
        return (self.hw.swap_latency
                + n_pages * self.kv_page_bytes() / self.hw.host_bw)

    def _tp_penalty(self, tp: int, n_layers: int) -> float:
        """Inter-chip sync overhead of tensor parallelism (2 allreduce/layer).

        This is what makes TP2 the worst deployment in the paper (§4.3)."""
        if tp <= 1:
            return 0.0
        return 2.0 * n_layers * self.hw.tp_allreduce_lat * (tp - 1)

    def _eff_kv(self, kv_len: float) -> float:
        w = self.cfg.sliding_window
        return min(kv_len, w) if w else kv_len

    # ---- payload sizes ------------------------------------------------------
    def param_bytes(self) -> float:
        return self.cfg.active_param_count() * self.hw.dtype_bytes

    def kv_bytes_per_token(self) -> float:
        """P->D payload per token: attention KV (+ amortized SSM state)."""
        cfg = self.cfg
        b = len(cfg.attn_layers) * 2 * cfg.kv_dim * self.hw.dtype_bytes
        return b

    def ssm_state_bytes(self) -> float:
        cfg = self.cfg
        if cfg.ssm is None:
            return 0.0
        nh = cfg.ssm.n_heads(cfg.d_model)
        per_layer = nh * cfg.ssm.head_dim * cfg.ssm.state_dim * 4  # f32
        return len(cfg.ssm_layers) * per_layer

    def kv_page_bytes(self) -> float:
        """Bytes of one KV pool page across all attention layers
        (0 when the layout is dense)."""
        return self.page_tokens * self.kv_bytes_per_token()

    def kv_page_bytes_per_layer(self) -> float:
        """One layer's slice of a KV page — the rounding quantum for
        per-layer transfer planning (kv_transfer.plan(page_bytes=...))."""
        n_attn = max(len(self.cfg.attn_layers), 1)
        return self.kv_page_bytes() / n_attn

    def kv_bytes(self, prompt_len: int) -> float:
        """Total P->D payload for one request (page-rounded when paged)."""
        eff = self._eff_kv(prompt_len)
        if self.page_tokens:
            eff = math.ceil(eff / self.page_tokens) * self.page_tokens
        return self.kv_bytes_per_token() * eff + self.ssm_state_bytes()

    def feature_bytes(self, n_tokens: int) -> float:
        """E->P payload (projected features, d_model wide — Table 3)."""
        return n_tokens * self.cfg.d_model * self.hw.dtype_bytes

    # ---- transfers ----------------------------------------------------------
    def transfer_time(self, nbytes: float, with_handshake: bool = True) -> float:
        t = nbytes / self.hw.link_bw
        return t + (self.hw.handshake if with_handshake else 0.0)

    def recover_transfer(self, plan, injector, policy, key=None,
                         replan: bool = True):
        """Deliver a transfer plan through the fault plane: re-schedules
        the plan's groups under the injector's handshake/wire faults with
        the retry policy's backoff, falling back to a fresh grouped plan
        for only the missing groups (kv_transfer.recover_plan), using
        THIS hardware profile's handshake latency and link bandwidth —
        the hook that charges retry time into simulator and cluster
        latency accounting. Returns (recovered_plan, TransferRecovery);
        raises TransferError when a group cannot be delivered at all."""
        from repro.core import kv_transfer
        return kv_transfer.recover_plan(
            plan, injector=injector, policy=policy,
            handshake=self.hw.handshake, link_bw=self.hw.link_bw,
            key=key, replan=replan)

    def feature_transfer_time(self, nbytes: float) -> float:
        """E->P feature movement through the MM Store path."""
        return nbytes / self.hw.store_bw

    def dispatch_latency(self, nbytes: float) -> float:
        """Cross-instance scheduling latency (paper Table 3): scheduler
        tick + batch formation + local cache write of the feature. The
        write path is marginally faster than the store fetch (~5%), so for
        very large features (4K images) the transfer outruns scheduling
        and overlap dips below 100% — exactly the paper's Table 3 shape."""
        return self.hw.dispatch_base + nbytes / (self.hw.store_bw * 1.05)

    def per_layer_kv_bytes(self, prompt_len: int) -> float:
        cfg = self.cfg
        n_attn = max(len(cfg.attn_layers), 1)
        return self.kv_bytes(prompt_len) / n_attn

    def per_layer_prefill_time(self, prompt_len: int, chips: int = 1,
                               tp: int = 1,
                               cached_prefix: float = 0.0) -> float:
        return self.prefill_time(prompt_len, chips, tp,
                                 cached_prefix) / self.cfg.n_layers

"""Paged KV-cache page pool (vLLM-style block allocator, ref-counted).

The device-side KV pool is a flat array of fixed-size pages shared by
every decode slot: ``(n_repeats, n_pages, page_size, n_kv, head_dim)``
per attention pattern position (see ``layers.PagedAttnCache``). This
module is the HOST-side bookkeeping around it:

* :class:`PagePool` — a ref-counted free-list allocator over physical
  page ids. ``alloc`` hands out pages at refcount 1; ``ref`` adds a
  holder (prefix sharing: the radix tree and every request retaining a
  shared prompt page each hold one ref); ``free``/``unref`` drops one
  and returns the page to the free list only when the last holder lets
  go. Physical page 0 is reserved as the *trash page*: unmapped
  block-table entries point at it, so decode writes from inactive slots
  and prefill writes past a request's last page land somewhere harmless
  instead of corrupting live pages.
* :class:`PagedKVPayload` — the P->D handoff unit. Instead of a full
  cache pytree it names the request's physical pages in the *source*
  engine's pool plus the small per-slot side state (SSM state, cross-KV,
  length). Inserting into the same engine is a pure block-table update
  (zero KV bytes moved); inserting into another engine gathers/scatters
  only those pages — O(one request's pages), never O(pool). Payload
  pages may be shared (prefix-cache hits): the payload holds ONE ref per
  page, released on insert-into-another-engine or ``release_payload``.

Leak auditing: ``assert_balanced`` cross-checks the allocator against
the holders the caller believes exist (slots, radix-tree retentions) —
engine/cluster tests call it after draining.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Sequence

import numpy as np

TRASH_PAGE = 0


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` (at least one)."""
    return max(1, -(-int(n_tokens) // page_size))


class PagePool:
    """Ref-counted allocator over the physical pages of one engine's pool.

    Page ids are ints in [1, n_pages); page 0 is the reserved trash page
    and is never handed out. A page is *used* while any holder refs it;
    ``_refs`` doubles as the O(1) membership check that used to scan the
    free list (the old O(n^2) double-free check).
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need n_pages >= 2 (page 0 is reserved)")
        if page_size < 1:
            raise ValueError("page_size must be positive")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        # LIFO free list: recently freed pages are re-used first (their
        # contents are most likely still resident in cache hierarchies).
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._refs: Dict[int, int] = {}
        # high-water mark of used pages (benchmarks: chunked-prefill
        # memory accounting)
        self.peak_used = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return pages_for(n_tokens, self.page_size)

    def refcount(self, page: int) -> int:
        return self._refs.get(int(page), 0)

    def alloc(self, n: int) -> np.ndarray:
        """Pop ``n`` physical page ids at refcount 1; raises RuntimeError
        when exhausted."""
        if n <= 0:
            return np.zeros((0,), np.int32)
        if n > len(self._free):
            raise RuntimeError(
                f"KV page pool exhausted: requested {n} pages, "
                f"{len(self._free)}/{self.n_pages - 1} free")
        out = self._free[-n:][::-1]
        del self._free[-n:]
        for p in out:
            self._refs[p] = 1
        self.peak_used = max(self.peak_used, self.n_used)
        return np.asarray(out, np.int32)

    def ref(self, pages: Sequence[int]) -> None:
        """Add one holder to each (already-allocated) page."""
        for p in pages:
            p = int(p)
            if p not in self._refs:
                raise ValueError(f"ref of unallocated page {p}")
            self._refs[p] += 1

    def free(self, pages: Sequence[int]) -> None:
        """Drop one holder per page; a page returns to the free list when
        its last holder releases it (``unref`` is an alias)."""
        for p in pages:
            p = int(p)
            if p == TRASH_PAGE:
                raise ValueError("cannot free the reserved trash page")
            if not (0 < p < self.n_pages):
                raise ValueError(f"page id {p} out of range")
            if p not in self._refs:
                raise ValueError(f"double free of page {p}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                self._free.append(p)

    unref = free

    def assert_balanced(self, holders: Iterable[Sequence[int]] = ()) -> None:
        """Leak assertion: the allocator's view must match the holders the
        caller knows about (each element of ``holders`` is one holder's
        page-id list — a slot's block-table row, a payload, the radix
        tree's retained pages). Raises AssertionError on any leaked page,
        ref-count mismatch, or free-list corruption."""
        expect: Dict[int, int] = {}
        for h in holders:
            for p in h:
                p = int(p)
                if p != TRASH_PAGE:
                    expect[p] = expect.get(p, 0) + 1
        assert len(self._free) + len(self._refs) == self.n_pages - 1, (
            f"pool accounting broken: {len(self._free)} free + "
            f"{len(self._refs)} used != {self.n_pages - 1}")
        assert len(set(self._free)) == len(self._free), \
            "free list contains duplicates"
        assert not (set(self._free) & set(self._refs)), \
            "page both free and referenced"
        leaked = {p: r for p, r in self._refs.items() if p not in expect}
        assert not leaked, f"leaked pages (refs with no holder): {leaked}"
        for p, want in expect.items():
            got = self._refs.get(p, 0)
            assert got == want, (
                f"page {p}: {got} refs but {want} holders")


@dataclass
class PagedKVPayload:
    """One prefilled request's KV, by reference into the source pool.

    source        — the Engine whose pool holds the pages.
    page_ids      — (n_pages,) physical ids in the source pool, in sequence
                    order (page j holds tokens [j*page, (j+1)*page)). Pages
                    shared via the prefix cache appear here too; the payload
                    owns one ref on every listed page.
    n_tokens      — true KV length (prompt + multimodal tokens).
    side          — batch-1 slot state pytree: {"ssm", "cross", "len"}.
    kv_nbytes     — attention-KV bytes these pages occupy across all layers
                    (what a cross-engine insert actually moves).
    cached_tokens — prompt tokens served from the prefix cache (prefill
                    computed only the remaining suffix).
    chunks        — streaming segments of a CHUNKED prefill, in order:
                    (computed_tokens, n_pages) per segment. A leading
                    (0, n) entry is the cached-prefix segment (ready
                    before any compute). Empty for monolithic prefill.
                    Sum of n_pages == len(page_ids); the transfer
                    planner uses it to ship segment k while segment k+1
                    computes (kv_transfer.plan_chunked).
    """

    source: Any
    page_ids: np.ndarray
    n_tokens: int
    side: Dict[str, Any] = field(default_factory=dict)
    kv_nbytes: int = 0
    cached_tokens: int = 0
    chunks: List[tuple] = field(default_factory=list)

    @property
    def n_pages(self) -> int:
        return len(self.page_ids)

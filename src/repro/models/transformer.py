"""Unified decoder backbone.

One code path covers every assigned arch: per-layer pattern of
{attn | swa | ssm} mixers and {mlp | moe | none} ffns, optional encoder
(whisper) and optional multimodal embedding merge (VLM / audio / early
fusion). Layers run under ``lax.scan`` over pattern repeats so 40-layer
models lower to compact HLO for the 512-chip dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.partitioning import shard


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def make_caches(cfg: ModelConfig, batch: int, max_len: int, *,
                dtype=jnp.bfloat16, kv_dtype=None, abstract: bool = False,
                for_decode: bool = False, layout: str = "dense",
                page_size: int = 16, n_pages: int = 0,
                with_attn: bool = True) -> Dict[str, Any]:
    """Cache pytree for serving. One entry per pattern position.

    for_decode=True clamps sliding-window caches to the window (ring
    buffer) — decode-only dry-runs. Prefill-capable caches keep max_len so
    a full prompt fits before eviction.
    kv_dtype: storage dtype for attention KV only (e.g. fp8_e4m3 — the
    beyond-paper decode optimization in EXPERIMENTS.md §Perf); SSM state
    and conv tails keep ``dtype``/f32.
    layout="paged": attention KV lives in a shared page pool of
    ``n_pages`` physical pages of ``page_size`` tokens (page 0 reserved
    as trash — see serving.kv_pool) and the pytree grows a "pages" block
    table (batch, max_len // page_size). Sliding-window caches are not
    ring-clamped on the paged path — the window is enforced by masking,
    and page-level eviction is the follow-up that reclaims the memory.
    SSM state and cross-KV stay slot-indexed (fixed per-slot size).
    with_attn=False skips the attention-KV allocations (entries stay
    None) — for side-state-only pytrees whose "attn" the caller swaps
    in from a shared page pool (paged prefill staging).
    """
    kv_dtype = kv_dtype or dtype
    paged = layout == "paged"
    if paged:
        if max_len % page_size:
            raise ValueError(
                f"max_len {max_len} not a multiple of page_size {page_size}")
        if n_pages < 2:
            raise ValueError("paged layout needs n_pages >= 2 "
                             "(page 0 is the reserved trash page)")
    attn = []
    ssm = []
    for spec in cfg.pattern:
        if spec.mixer in ("attn", "swa"):
            if not with_attn:
                attn.append(None)
                ssm.append(None)
                continue
            if paged:
                attn.append(L.make_paged_attn_cache(
                    cfg, cfg.n_repeats, n_pages, page_size, kv_dtype,
                    abstract))
                ssm.append(None)
                continue
            window = (cfg.sliding_window
                      if spec.mixer == "swa" and for_decode else None)
            attn.append(L.make_attn_cache(cfg, cfg.n_repeats, batch, max_len,
                                          window, kv_dtype, abstract))
            ssm.append(None)
        elif spec.mixer == "ssm":
            attn.append(None)
            ssm.append(S.make_ssm_cache(cfg, cfg.n_repeats, batch, dtype,
                                        abstract))
        else:
            attn.append(None)
            ssm.append(None)
    cross = None
    if cfg.encoder is not None:
        t = cfg.encoder.n_ctx
        kshape = (cfg.n_repeats, batch, t, cfg.n_kv_heads, cfg.head_dim)
        pshape = (cfg.n_repeats, batch, t)
        if abstract:
            cross = (jax.ShapeDtypeStruct(kshape, dtype),
                     jax.ShapeDtypeStruct(kshape, dtype),
                     jax.ShapeDtypeStruct(pshape, jnp.int32))
        else:
            cross = (jnp.zeros(kshape, dtype), jnp.zeros(kshape, dtype),
                     jnp.full(pshape, -1, jnp.int32))
    lengths = (jax.ShapeDtypeStruct((batch,), jnp.int32) if abstract
               else jnp.zeros((batch,), jnp.int32))
    caches = {"attn": tuple(attn), "ssm": tuple(ssm), "cross": cross,
              "len": lengths}
    if paged:
        tshape = (batch, max_len // page_size)
        caches["pages"] = (jax.ShapeDtypeStruct(tshape, jnp.int32) if abstract
                           else jnp.zeros(tshape, jnp.int32))
    return caches


def cache_pspecs(cfg: ModelConfig, rules, layout: str = "dense"
                 ) -> Dict[str, Any]:
    """PartitionSpecs matching make_caches structure.

    KV-cache sharding adapts per arch: heads when n_kv_heads divides the
    model axis (classic TP), else the sequence dim (flash-decode style) —
    e.g. smollm's kv=3 or glm4's kv=2 cannot split 16 ways by head.
    layout="paged": the pool's page axis takes the role of the sequence
    axis (pages spread flash-decode style); the block table and lengths
    stay batch-sharded.
    """
    from repro.models.partitioning import logical_to_pspec as lp
    paged = layout == "paged"
    head_ok = (rules is not None and rules.size("kv_heads") > 1 and
               cfg.n_kv_heads % rules.size("kv_heads") == 0)
    seq_pref = rules is not None and rules.size("kv_seq") > 1
    if paged:
        # (repeats, n_pages, page, nkv, hd)
        kv_axes = ("layers", "kv_seq", None,
                   "kv_heads" if head_ok else None, None)
        pos_axes = None
    elif rules is not None and not head_ok and not seq_pref:
        # fall back to sequence sharding on whatever axis 'kv_heads' used
        kv_axes = ("layers", "batch", "kv_heads", None, None)
        pos_axes = ("layers", "batch", "kv_heads")
    else:
        kv_axes = ("layers", "batch", "kv_seq",
                   "kv_heads" if head_ok else None, None)
        pos_axes = ("layers", "batch", "kv_seq")
    attn, ssm = [], []
    for spec in cfg.pattern:
        if spec.mixer in ("attn", "swa"):
            kv = lp(kv_axes, rules)
            if paged:
                attn.append(L.PagedAttnCache(kv, kv))
            else:
                pos = lp(pos_axes, rules)
                attn.append(L.AttnCache(kv, kv, pos))
            ssm.append(None)
        elif spec.mixer == "ssm":
            st = lp(("layers", "batch", "act_heads", None, None), rules)
            cv = lp(("layers", "batch", None, "act_inner"), rules)
            attn.append(None)
            ssm.append(S.SSMCache(st, cv))
        else:
            attn.append(None)
            ssm.append(None)
    cross = None
    if cfg.encoder is not None:
        kv = lp(("layers", "batch", None, "kv_heads", None), rules)
        cross = (kv, kv, lp(("layers", "batch", None), rules))
    specs = {"attn": tuple(attn), "ssm": tuple(ssm), "cross": cross,
             "len": lp(("batch",), rules)}
    if paged:
        specs["pages"] = lp(("batch", None), rules)
    return specs


# ---------------------------------------------------------------------------
# Embedding / input merge
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, tokens,
                 mm_embeds: Optional[jax.Array] = None):
    """tokens: (B, S_text) int32; mm_embeds: (B, n_mm, feature_dim) or None.

    Multimodal embeddings (from the stubbed frontend) are projected to
    d_model and PREPENDED to the text sequence (early fusion). Returns
    (x (B, S, d), positions (B, S)).
    """
    x = params["embed"][tokens]                       # (B, S_t, d)
    if mm_embeds is not None:
        mm = mm_embeds.astype(x.dtype) @ params["projector"]
        x = jnp.concatenate([mm, x], axis=1)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return shard(x, "batch", None, "act_embed"), positions


def scatter_mm_features(x, positions, mm_feats, mm_start):
    """Overwrite image-token positions of the embedding stream with
    projected multimodal features (the Encode-stage E->P hand-off).

    x: (B, S, d) token embeddings for this (possibly suffix) chunk;
    positions: (B, S) ABSOLUTE positions; mm_feats: (B, n_mm, d) already
    projected to d_model; mm_start: scalar/(B,) absolute position of the
    first image token. Positions outside [mm_start, mm_start + n_mm) keep
    their text embeddings, so a chunk that only overlaps part of the image
    run scatters exactly its slice.
    """
    n_mm = mm_feats.shape[1]
    start = jnp.asarray(mm_start, jnp.int32)
    if start.ndim == 0:
        start = jnp.broadcast_to(start, (x.shape[0],))
    rel = positions - start[:, None]                  # (B, S)
    valid = (rel >= 0) & (rel < n_mm)
    gathered = jnp.take_along_axis(
        mm_feats.astype(x.dtype),
        jnp.clip(rel, 0, n_mm - 1)[..., None], axis=1)
    return jnp.where(valid[..., None], gathered, x)


def lm_logits(params, cfg: ModelConfig, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return shard(h @ w.astype(h.dtype), "batch", None, "act_vocab")


# ---------------------------------------------------------------------------
# Encoder (whisper-style)
# ---------------------------------------------------------------------------

def run_encoder(params, cfg: ModelConfig, frames):
    """frames: (B, T, feature_dim) stub embeddings -> (B, T, d_model)."""
    enc = params["encoder"]
    x = frames.astype(params["projector"].dtype) @ params["projector"]
    x = x + enc["pos_embed"][None, : x.shape[1]].astype(x.dtype)
    b, t = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    def body(carry, p):
        h = carry
        h, _ = L.attention_block(p["attn"], h, positions, cfg, causal=False)
        h = L.mlp_block(p["mlp"], h, cfg)
        return h, None

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return L.rms_norm(x, enc["final_norm"], cfg.norm_eps), positions


# ---------------------------------------------------------------------------
# Decoder stack
# ---------------------------------------------------------------------------

def run_decoder(params, cfg: ModelConfig, x, positions, *,
                caches: Optional[Dict[str, Any]] = None,
                enc_out: Optional[Tuple[jax.Array, jax.Array]] = None,
                remat: bool = False,
                prefix_len: Optional[jax.Array] = None,
                pos_base: Optional[jax.Array] = None):
    """Run all decoder layers.

    caches: cache pytree from make_caches (serving) or None (training).
    enc_out: (enc_hidden, enc_pos) — only during prefill/training of an
      enc-dec arch; during decode the cross-KV comes from caches['cross'].
    prefix_len / pos_base: paged suffix-prefill against a cached prefix
      (see layers.attention_block) — x covers positions from the
      page-aligned ``pos_base`` only.
    Returns (h, new_caches, aux_loss).
    """
    pat = cfg.pattern
    cur_len = caches["len"] if caches is not None else None
    pages = caches.get("pages") if caches is not None else None
    attn_cls = L.PagedAttnCache if pages is not None else L.AttnCache
    decode = caches is not None and x.shape[1] == 1

    def body(carry, xs):
        h, aux = carry
        p_list, attn_c, ssm_c, cross_c = xs
        new_attn, new_ssm = [], []
        new_cross = None
        for i, spec in enumerate(pat):
            p = p_list[i]
            if spec.mixer in ("attn", "swa"):
                window = cfg.sliding_window if spec.mixer == "swa" else None
                h, nc = L.attention_block(
                    p["attn"], h, positions, cfg, window=window,
                    cache=tuple(attn_c[i]) if attn_c[i] is not None else None,
                    cur_len=cur_len, pages=pages,
                    prefix_len=prefix_len, pos_base=pos_base)
                new_attn.append(attn_cls(*nc) if nc is not None else None)
                if cfg.encoder is not None:
                    if decode:
                        ckv = cross_c
                    else:
                        ckv = L.compute_cross_kv(p["attn"], enc_out[0],
                                                 enc_out[1], cfg)
                        new_cross = ckv
                    h = L.cross_attention_block(p["attn"], h, positions, ckv,
                                                cfg)
            elif spec.mixer == "ssm":
                h, nc = S.ssm_block(
                    p["ssm"], h, cfg,
                    cache=tuple(ssm_c[i]) if ssm_c[i] is not None else None,
                    positions=positions)
                new_ssm.append(S.SSMCache(*nc) if nc is not None else None)
            else:
                new_attn.append(None)
                new_ssm.append(None)
            if spec.ffn == "mlp":
                h = L.mlp_block(p["mlp"], h, cfg)
            elif spec.ffn == "moe":
                h, a = M.moe_block(p["moe"], h, cfg)
                aux = aux + a
            if spec.mixer in ("attn", "swa"):
                new_ssm.append(None)
            elif spec.mixer == "ssm":
                new_attn.append(None)
        ys = (tuple(new_attn), tuple(new_ssm), new_cross)
        return (h, aux), ys

    if remat:
        body = jax.checkpoint(body)

    attn_xs = (caches["attn"] if caches is not None
               else tuple(None for _ in pat))
    ssm_xs = (caches["ssm"] if caches is not None
              else tuple(None for _ in pat))
    cross_xs = caches["cross"] if caches is not None else None
    xs = (params["blocks"], attn_xs, ssm_xs, cross_xs)
    (h, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    new_attn, new_ssm, new_cross = ys

    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)

    new_caches = None
    if caches is not None:
        step = x.shape[1] if not decode else 1
        new_caches = {
            "attn": new_attn, "ssm": new_ssm,
            "cross": (new_cross if cfg.encoder is not None and not decode
                      else caches["cross"]),
            "len": caches["len"] + (jnp.int32(step) if decode
                                    else positions.shape[1]),
        }
        if pages is not None:
            new_caches["pages"] = pages
    return h, new_caches, aux

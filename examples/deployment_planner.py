"""SLO-driven deployment selection (paper §4.7): sweep deployments across
request rates and report the winner per SLO regime — the paper's radar
chart as a table.

    PYTHONPATH=src python examples/deployment_planner.py
"""
from repro.configs import get_config
from repro.core.simulator import SHAREGPT_4O, simulate

DEPLOYMENTS = ["TP1", "(E-PD)", "EP-D", "(E-P)-D", "(E-D)-P", "E-P-D"]
REGIMES = {
    "high_performance": dict(ttft=2000, tpot=50),    # strict both
    "fast_first_token": dict(ttft=800, tpot=80),     # TTFT-dominant
    "max_throughput": dict(ttft=8000, tpot=200),     # loose latency
}


def main():
    model = get_config("openpangu-7b-vl")
    for rate in (4.0, 8.0, 12.0):
        res = {d: simulate(model, d, SHAREGPT_4O, rate=rate,
                           n_requests=192, seed=21) for d in DEPLOYMENTS}
        print(f"\n== rate {rate} req/s ==")
        for regime, slo in REGIMES.items():
            best = max(DEPLOYMENTS, key=lambda d: (
                res[d].effective_throughput(slo["ttft"], slo["tpot"])))
            m = res[best]
            print(f"{regime:18s} -> {best:8s} "
                  f"(eff {m.effective_throughput(slo['ttft'], slo['tpot']):.0f}"
                  f" tok/s/chip, TTFT {m.mean_ttft_ms:.0f}ms, "
                  f"TPOT {m.mean_tpot_ms:.1f}ms)")


if __name__ == "__main__":
    main()

"""Unified kernel dispatch used by the model layers.

``attention`` / ``ssd`` route to the Pallas kernels when enabled
(``REPRO_USE_PALLAS=1`` or running on real TPU) and to the pure-jnp
references otherwise. The references are also the dry-run/roofline path:
XLA's cost_analysis sees the full math instead of an opaque custom call.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import dispatch


def attention(q, k, v, q_pos, kv_pos, *, window: Optional[int] = None,
              causal: bool = True) -> jax.Array:
    """q: (b, s, nq, hd); k, v: (b, S, nkv, hd). See flash_attention/ref.py."""
    if dispatch.use_pallas():
        if q.shape[1] == 1 and causal:
            from repro.kernels.decode_attention.kernel import decode_attention
            out = decode_attention(q[:, 0], k, v, q_pos[:, 0], kv_pos,
                                   window=window,
                                   interpret=dispatch.interpret())
            return out[:, None]
        from repro.kernels.flash_attention.kernel import flash_attention
        return flash_attention(q, k, v, q_pos, kv_pos, window=window,
                               causal=causal, interpret=dispatch.interpret())
    from repro.kernels.flash_attention.ref import attention_ref
    return attention_ref(q, k, v, q_pos, kv_pos, window=window, causal=causal)


def paged_attention(q, k_pool, v_pool, block_tbl, lengths, *,
                    window: Optional[int] = None) -> jax.Array:
    """Single-token attention over a paged KV pool.

    q: (b, nq, hd); k_pool, v_pool: (P, page, nkv, hd);
    block_tbl: (b, max_pages) int32; lengths: (b,) valid tokens
    (including the current one). See paged_decode_attention/ref.py.
    """
    if dispatch.use_pallas():
        from repro.kernels.paged_decode_attention.kernel import (
            paged_decode_attention)
        return paged_decode_attention(q, k_pool, v_pool, block_tbl, lengths,
                                      window=window,
                                      interpret=dispatch.interpret())
    from repro.kernels.paged_decode_attention.ref import (
        paged_decode_attention_ref)
    return paged_decode_attention_ref(q, k_pool, v_pool, block_tbl, lengths,
                                      window=window)


def ssd(x, dt, a, b, c, d_skip, chunk: int, init_state=None):
    """Chunked SSD scan. See ssd_scan/ref.py for shapes."""
    if dispatch.use_pallas():
        from repro.kernels.ssd_scan.kernel import ssd_scan
        return ssd_scan(x, dt, a, b, c, d_skip, chunk, init_state,
                        interpret=dispatch.interpret())
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, a, b, c, d_skip, chunk, init_state)

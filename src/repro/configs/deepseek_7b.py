"""deepseek-7b [dense] — llama-arch, MHA (kv=32).  [arXiv:2401.02954]"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    arch_type="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    pattern=(LayerSpec("attn", "mlp"),),
    source="arXiv:2401.02954",
)

"""Train a ~small multimodal model for a few hundred steps on synthetic
data (deliverable (b)'s end-to-end training driver, CPU-scale).

    PYTHONPATH=src python examples/train_mm.py [--steps 200]
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.models.model import init_params
from repro.models.params import count_params
from repro.training.data import synthetic_batches
from repro.training.optimizer import AdamW
from repro.training.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llava-next-mistral-7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=48)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={count_params(params):,} "
          f"(multimodal={cfg.frontend is not None})")

    opt = AdamW(lr=2e-3, warmup_steps=max(args.steps // 10, 1))
    step = jax.jit(make_train_step(cfg, opt, remat=False))
    state = opt.init(params)

    t0 = time.time()
    losses = []
    data = synthetic_batches(cfg, args.batch, args.seq, args.steps,
                             mm=cfg.frontend is not None and
                             cfg.encoder is None)
    for i, batch in enumerate(data):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            dt = (time.time() - t0) / (i + 1)
            print(f"step {i:4d}  loss {losses[-1]:.4f}  ({dt*1e3:.0f} ms/step)")
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'LEARNED' if losses[-1] < losses[0] - 0.5 else 'check lr'})")


if __name__ == "__main__":
    main()

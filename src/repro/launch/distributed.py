"""Multi-host bootstrap for real pod deployments.

On real TPU slices, each host process calls :func:`ensure_initialized`
before touching jax devices; the coordinator address / process ids come
from the environment set by ``launch/pod.sh``. On the CPU dev container
this is a no-op (single process) so every driver can call it
unconditionally.
"""
from __future__ import annotations

import os

import jax

_initialized = False


def ensure_initialized() -> bool:
    """Initialize jax.distributed from pod.sh's environment. Returns True
    if a multi-process runtime was set up."""
    global _initialized
    if _initialized:
        return True
    addr = os.environ.get("JAX_COORDINATOR_ADDRESS")
    nproc = int(os.environ.get("JAX_NUM_PROCESSES", "1"))
    if addr is None or nproc <= 1:
        return False
    jax.distributed.initialize(
        coordinator_address=addr,
        num_processes=nproc,
        process_id=int(os.environ["JAX_PROCESS_ID"]))
    _initialized = True
    return True


def is_multi_pod() -> bool:
    return int(os.environ.get("REPRO_MULTI_POD", "1")) > 1

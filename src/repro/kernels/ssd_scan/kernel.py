"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (batch, heads, chunks) — chunks iterate sequentially per core, the
running (P x N) state lives in VMEM scratch. Intra-chunk work is pure
matmul (MXU): the (L x L) decay-masked score block, the (L x N) chunk
state update, and the (L x P) outputs. This is the TPU-native adaptation
of the SSD algorithm (arXiv:2405.21060): the GPU version leans on warp
shuffles for the intra-chunk cumsum; here the cumsum is a vector op over
an (L,) VMEM tile and everything else is systolic matmul.

B and C are shared across heads (single SSD group) — their index_map
ignores the head coordinate, so each (b, chunk) B/C tile is fetched once
per head loop from HBM but never duplicated in HBM itself.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, dsk_ref, s0_ref,
            y_ref, fin_ref, state_ref, *, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0, 0].astype(jnp.float32)           # (L, P)
    dt = dt_ref[0, 0, 0, :, 0].astype(jnp.float32)   # (L,)
    a = a_ref[0, 0]                                  # scalar
    bm = b_ref[0, 0].astype(jnp.float32)             # (L, N)
    cm = c_ref[0, 0].astype(jnp.float32)             # (L, N)
    dsk = dsk_ref[0, 0]

    log_da = dt * a                                  # (L,)
    cum = jnp.cumsum(log_da)                         # (L,)
    L = x.shape[0]

    # intra-chunk: y_diag[i] = sum_{j<=i} (C_i.B_j) exp(cum_i-cum_j) dt_j x_j
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    tril = ii >= jj
    decay = jnp.where(tril, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = cb * decay                              # (L, L)
    xdt = x * dt[:, None]                            # (L, P)
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y_off[i] = (C_i exp(cum_i)) . state_prev^T
    state = state_ref[...]                           # (P, N)
    c_in = cm * jnp.exp(cum)[:, None]                # (L, N)
    y += jax.lax.dot_general(c_in, state, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)

    y_ref[0, 0, 0] = (y + x * dsk).astype(y_ref.dtype)

    # state update: state = state * exp(cum_last) + xdt^T @ (B * decay_to_end)
    decay_end = jnp.exp(cum[-1] - cum)               # (L,)
    b_in = bm * (decay_end * dt)[:, None]            # (L, N)
    new_state = state * jnp.exp(cum[-1]) + jax.lax.dot_general(
        x, b_in, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    state_ref[...] = new_state

    @pl.when(ci == nc - 1)
    def _done():
        fin_ref[0, 0] = new_state


def ssd_scan(x, dt, a, b, c, d_skip, chunk: int,
             init_state: Optional[jax.Array] = None, *,
             interpret: bool = False):
    """Shapes as ssd_chunked: x (B,S,H,P), dt (B,S,H), a (H,), b/c (B,S,N),
    d_skip (H,), init_state (B,H,P,N) or None. Returns (y, final_state)."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, f"seq {S} % chunk {chunk} != 0"

    xt = jnp.moveaxis(x, (1, 2), (2, 1)).reshape(B, H, nc, chunk, P)
    dtt = jnp.moveaxis(dt, 1, 2).reshape(B, H, nc, chunk, 1)
    bt = b.reshape(B, nc, chunk, N)
    ct = c.reshape(B, nc, chunk, N)
    a2 = jnp.broadcast_to(a.astype(jnp.float32)[None], (B, H))
    d2 = jnp.broadcast_to(d_skip.astype(jnp.float32)[None], (B, H))
    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    grid = (B, H, nc)
    kern = functools.partial(_kernel, nc=nc)
    y, fin = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda bi, h, ci: (bi, h, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1), lambda bi, h, ci: (bi, h, ci, 0, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, ci: (bi, h)),
            pl.BlockSpec((1, 1, chunk, N), lambda bi, h, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda bi, h, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1), lambda bi, h, ci: (bi, h)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, ci: (bi, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda bi, h, ci: (bi, h, ci, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, ci: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, chunk, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, a2, bt, ct, d2, s0)
    y = jnp.moveaxis(y.reshape(B, H, S, P), 1, 2)    # (B,S,H,P)
    return y, fin

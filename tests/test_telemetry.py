"""Stage-level telemetry: quantiles, registry, tracer, attribution,
trace export, and the no-behavior-change guarantee when disabled."""
import json

import pytest

from repro.core.telemetry import (COMPONENTS, NULL_SPAN, NULL_TRACER,
                                  LatencyAccountant, MetricsRegistry,
                                  Tracer, quantile)
from repro.core.trace_export import (overlap, to_trace_events,
                                     validate_trace, write_trace)


# ---------------------------------------------------------------------------
# quantile (the single implementation behind every p50/p99 in the repo)
# ---------------------------------------------------------------------------

def test_quantile_empty_is_zero():
    assert quantile([], 0.5) == 0.0
    assert quantile([], 0.99) == 0.0


def test_quantile_single_sample_every_p():
    for p in (0.0, 0.5, 0.99, 1.0):
        assert quantile([7.5], p) == 7.5


def test_quantile_interpolates():
    xs = [0.0, 10.0]
    assert quantile(xs, 0.5) == 5.0
    assert quantile(xs, 0.25) == 2.5
    assert quantile(list(range(101)), 0.99) == 99.0


def test_quantile_clamps_and_sorts():
    xs = [3.0, 1.0, 2.0]
    assert quantile(xs, -1.0) == 1.0
    assert quantile(xs, 2.0) == 3.0
    assert quantile(xs, 0.5) == 2.0     # unsorted input


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_get_or_create_identity():
    r = MetricsRegistry()
    a = r.counter("x_total", engine="D0")
    b = r.counter("x_total", engine="D0")
    assert a is b
    a.inc(3)
    assert r.value("x_total", engine="D0") == 3.0
    assert r.value("x_total", engine="D1") == 0.0   # never touched


def test_registry_total_sums_label_sets():
    r = MetricsRegistry()
    r.counter("retries_total", site="a").inc(2)
    r.counter("retries_total", site="b").inc(5)
    assert r.total("retries_total") == 7.0


def test_registry_type_conflict_raises():
    r = MetricsRegistry()
    r.counter("m")
    with pytest.raises(ValueError):
        r.gauge("m", pool="p")


def test_counter_rejects_decrease():
    r = MetricsRegistry()
    with pytest.raises(ValueError):
        r.counter("c").inc(-1)


def test_gauge_max_high_water_mark():
    g = MetricsRegistry().gauge("peak")
    g.max(5)
    g.max(3)
    assert g.value == 5.0


def test_snapshot_shape_and_histogram():
    r = MetricsRegistry()
    r.counter("c_total", k="v").inc()
    r.gauge("g").set(0.5)
    h = r.histogram("lat_ms")
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    snap = r.snapshot()
    assert snap["counters"]["c_total{k=v}"] == 1.0
    assert snap["gauges"]["g"] == 0.5
    hs = snap["histograms"]["lat_ms"]
    assert hs["count"] == 3 and hs["sum"] == 6.0 and hs["p50"] == 2.0
    json.dumps(snap)                    # JSON-able


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_disabled_tracer_records_nothing():
    t = Tracer(enabled=False)
    cm = t.span("phase", track="x", request_id=1)
    assert cm is NULL_SPAN              # shared no-op, zero allocation
    with cm:
        pass
    t.add("modeled", 0.0, 1.0)
    assert t.spans == []
    assert not t.want_decode_span(0)


def test_span_nesting_records_parent():
    t = Tracer(enabled=True, now=lambda: 1.0)
    with t.span("outer", track="e"):
        with t.span("inner", track="e"):
            pass
    t.assert_balanced()
    inner, outer = sorted(t.spans, key=lambda s: s.name)
    assert inner.parent == "outer" and outer.parent is None


def test_unbalanced_span_fails_audit():
    t = Tracer(enabled=True, now=lambda: 0.0)
    cm = t.span("leak", track="e")
    cm.__enter__()
    with pytest.raises(AssertionError):
        t.assert_balanced()


def test_add_rejects_backwards_span():
    t = Tracer(enabled=True)
    with pytest.raises(ValueError):
        t.add("bad", 2.0, 1.0)


def test_decode_sampling():
    t = Tracer(enabled=True, decode_sample=4)
    assert [s for s in range(8) if t.want_decode_span(s)] == [0, 4]
    with pytest.raises(ValueError):
        Tracer(decode_sample=0)


def test_null_tracer_is_disabled():
    assert not NULL_TRACER.enabled and NULL_TRACER.spans == []


# ---------------------------------------------------------------------------
# latency accountant (fake wall clock)
# ---------------------------------------------------------------------------

class FakeWall:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_accountant_wall_segments_charge_by_state():
    w = FakeWall()
    acc = LatencyAccountant(wall=w)
    acc.open(1)                          # state: queue
    w.t = 2.0
    acc.set_state(1, "compute")          # syncs: 2s of queue charged
    w.t = 5.0
    acc.close(1, n_output_tokens=4)      # 3s of compute
    rec = acc.records[1]
    assert rec.components["queue"] == pytest.approx(2.0)
    assert rec.components["compute"] == pytest.approx(3.0)
    assert rec.e2e == pytest.approx(5.0)
    rec.check(tol=0.0)


def test_accountant_advance_overrides_one_request():
    acc = LatencyAccountant()            # simulated time: no wall
    acc.open(1, "compute")
    acc.open(2, "queue")
    acc.advance(1.0, 2, "retry")         # 2 retries; 1 keeps computing
    assert acc.records[1].components["compute"] == pytest.approx(1.0)
    assert acc.records[2].components["retry"] == pytest.approx(1.0)
    assert acc.records[2].components["queue"] == 0.0


def test_accountant_note_is_zero_sum_and_clamped():
    acc = LatencyAccountant()
    acc.open(1, "queue")
    acc.advance(2.0)
    moved = acc.note(1, "swap", 5.0, source="queue")   # only 2s available
    assert moved == pytest.approx(2.0)
    rec = acc.records[1]
    assert rec.components["queue"] == 0.0
    assert rec.components["swap"] == pytest.approx(2.0)
    acc.close(1)
    rec.check(tol=0.0)                   # invariant survives the move


def test_accountant_ttft_snapshot_and_alias():
    acc = LatencyAccountant()
    acc.open(1, "compute")
    acc.advance(1.0)
    acc.mark_first_token(1)
    acc.alias(999, 1)
    acc.advance(0.5, 999, "transfer")    # billed to request 1
    acc.close(1, n_output_tokens=3)
    rec = acc.records[1]
    assert rec.ttft == pytest.approx(1.0)
    assert rec.ttft_components["compute"] == pytest.approx(1.0)
    assert rec.decode_components()["transfer"] == pytest.approx(0.5)
    assert rec.n_output_tokens == 3


def test_accountant_open_is_requeue_safe():
    acc = LatencyAccountant()
    acc.open(1, "queue")
    acc.advance(1.0)
    acc.open(1, "queue")                 # requeue: must not reset ledger
    assert acc.records[1].components["queue"] == pytest.approx(1.0)
    assert acc.n_open == 1
    with pytest.raises(AssertionError):
        acc.assert_all_closed()
    acc.close(1)
    acc.assert_all_closed()


def test_accountant_report_is_jsonable():
    acc = LatencyAccountant()
    acc.open(1, "compute")
    acc.advance(2.0)
    acc.close(1, 2)
    rep = acc.report()
    assert rep["n_requests"] == 1
    assert set(rep["mean_components_ms"]) == set(COMPONENTS)
    json.dumps(rep)


# ---------------------------------------------------------------------------
# trace export
# ---------------------------------------------------------------------------

def _traced():
    clk = {"t": 0.0}
    t = Tracer(enabled=True, now=lambda: clk["t"])
    with t.span("prefill", track="P0", request_id=1):
        clk["t"] = 1.0
    t.add("kv.wire", 0.5, 0.8, track="P0->D0", request_id=1)
    t.add("decode.step", 1.0, 1.2, track="D0")
    return t


def test_export_and_validate_roundtrip(tmp_path):
    t = _traced()
    path = tmp_path / "trace.json"
    n = write_trace(t, str(path))
    doc = json.loads(path.read_text())
    counts = validate_trace(doc, require_tracks=["P0", "D0"])
    assert n == 3 and counts == {"P0": 1, "P0->D0": 1, "D0": 1}
    # timestamps are microseconds of the tracer clock
    x = [e for e in doc["traceEvents"]
         if e["ph"] == "X" and e["name"] == "kv.wire"][0]
    assert x["ts"] == pytest.approx(0.5e6)
    assert x["dur"] == pytest.approx(0.3e6)


def test_validate_requires_tracks():
    doc = {"traceEvents": to_trace_events(_traced())}
    with pytest.raises(AssertionError):
        validate_trace(doc, require_tracks=["E0"])


def test_overlap_helper():
    doc = {"traceEvents": to_trace_events(_traced())}
    # wire [0.5, 0.8] rides under prefill [0.0, 1.0]
    assert overlap(doc, "P0", "prefill", "P0->D0", "kv.wire") == \
        pytest.approx(0.3)
    assert overlap(doc, "P0", "prefill", "D0", "decode.step") == 0.0


# ---------------------------------------------------------------------------
# integration: real cluster + simulator invariants
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smollm():
    import jax
    from repro.configs import get_config
    from repro.models.model import init_params
    cfg = get_config("smollm-135m").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _run_cluster(cfg, params, tracer=None, faults=None):
    from repro.core.cluster import EPDCluster
    from repro.serving.request import Request
    cl = EPDCluster(cfg, params, max_batch=2, max_len=64, paged=True,
                    page_size=8, chunked_prefill=True, prefill_chunk=8,
                    faults=faults, tracer=tracer)
    reqs = [Request(prompt_tokens=list(range(3 + i, 20 + i)),
                    max_new_tokens=6) for i in range(3)]
    for r in reqs:
        cl.submit(r)
    done = cl.run_until_done()
    assert len(done) == 3
    return cl, [r.output_tokens for r in reqs]


def test_cluster_attribution_invariants(smollm):
    from repro.core.faults import SITE_TRANSFER_WIRE, FaultPlan
    cfg, params = smollm
    tr = Tracer(enabled=True)
    cl, _ = _run_cluster(cfg, params, tracer=tr,
                         faults=FaultPlan(
                             seed=11, rates={SITE_TRANSFER_WIRE: 0.3}))
    tr.assert_balanced()
    cl.acc.assert_all_closed()
    cl.acc.check_all(tol=0.01)           # components sum to e2e
    # the retry component reconciles exactly with the registry counter
    assert cl.acc.component_total("retry") == \
        pytest.approx(cl.report.retry_time_total, abs=1e-9)
    # spans landed on the engine tracks the exporter renders
    tracks = tr.tracks()
    assert tracks.get("P0") and tracks.get("D0")
    doc = {"traceEvents": to_trace_events(tr)}
    validate_trace(doc, require_tracks=["P0", "D0"])


def test_cluster_tracing_disabled_no_behavior_change(smollm):
    cfg, params = smollm
    cl_off, out_off = _run_cluster(cfg, params, tracer=None)
    tr = Tracer(enabled=True)
    cl_on, out_on = _run_cluster(cfg, params, tracer=tr)
    # greedy outputs bit-identical with tracing on vs off
    assert out_on == out_off
    # untraced run recorded zero spans anywhere (NULL_TRACER untouched)
    assert cl_off.tracer.spans == [] and not cl_off.tracer.enabled
    assert len(tr.spans) > 0
    # counter migration: the registry agrees with the legacy names
    e = cl_on.prefill_engine
    assert e.prefill_tokens_total == \
        int(cl_on.metrics.value("prefill_tokens_total", engine="P0"))


def test_cluster_report_counter_backcompat(smollm):
    """The migrated ClusterReport counters read through to the registry."""
    from repro.core.faults import SITE_STORE_FETCH
    cfg, params = smollm
    cl, _ = _run_cluster(cfg, params)
    assert cl.report.store_retries == 0
    assert cl.report.transfer_retries == 0
    assert cl.report.transfer_replans == 0
    assert cl.report.retry_time_total == 0.0
    cl.metrics.counter("recovery_retries_total",
                       site=SITE_STORE_FETCH).inc(2)
    cl.metrics.counter("retry_time_seconds_total", site="transfer").inc(0.5)
    assert cl.report.store_retries == 2
    assert cl.report.retry_time_total == 0.5


def test_simulator_attribution_sums_exactly():
    import dataclasses
    from repro.configs import get_config
    from repro.core.faults import SITE_TRANSFER_WIRE, FaultPlan
    from repro.core.simulator import SHAREGPT_4O, simulate
    model = get_config("openpangu-7b-vl")
    ds = dataclasses.replace(SHAREGPT_4O, mm_fraction=0.25)
    m = simulate(model, "E-P-D", ds, rate=8.0, n_requests=24, seed=3,
                 kv_page_tokens=16, decode_kv_pages=512, preemption=True,
                 faults=FaultPlan(seed=7,
                                  rates={SITE_TRANSFER_WIRE: 0.05}))
    att = m.attribution
    assert att["n_requests"] == 24
    for r in att["requests"]:
        total = sum(r["components_ms"].values())
        assert total == pytest.approx(r["e2e_ms"], rel=0.01, abs=1e-6)
    # registry snapshot rides along under the common key
    assert m.telemetry["counters"][
        f"recovery_retries_total{{site=transfer}}"] == m.transfer_retries


def test_simulator_tracing_does_not_change_results():
    import dataclasses
    from repro.configs import get_config
    from repro.core.simulator import SHAREGPT_4O, simulate
    model = get_config("openpangu-7b-vl")
    ds = dataclasses.replace(SHAREGPT_4O, mm_fraction=0.5)
    kw = dict(rate=8.0, n_requests=16, seed=3, kv_page_tokens=16)
    off = simulate(model, "E-P-D", ds, **kw)
    tr = Tracer(enabled=True)
    on = simulate(model, "E-P-D", ds, tracer=tr, **kw)
    assert on.mean_ttft_ms == off.mean_ttft_ms
    assert on.p99_tpot_ms == off.p99_tpot_ms
    assert on.makespan == off.makespan
    assert len(tr.spans) > 0
    tr.assert_balanced()

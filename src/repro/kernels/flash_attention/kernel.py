"""Pallas TPU flash attention (prefill): online-softmax, BlockSpec-tiled.

Grid: (batch, q_heads, q_blocks, kv_blocks) — the last dim iterates
sequentially on a TensorCore, so the (m, l, acc) running state lives in
VMEM scratch across kv-block steps. GQA is handled in the k/v index_map
(q-head h reads kv-head h // group), so KV is never materialized per
q-head in HBM.

Block sizes default to (128, 512) — q tile rows are MXU-aligned (128) and
the kv tile keeps the f32 scores block (128 x 512 = 256 KiB) plus k/v
tiles comfortably inside the ~16 MiB VMEM budget of a v5e core.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, causal: bool,
            window: Optional[int], nk: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qpos_ref[0]                               # (bq,)
    kpos = kpos_ref[0]                               # (bk,)
    valid = (kpos[None, :] >= 0) & (qpos[:, None] >= 0)
    if causal:
        valid &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            valid &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid, p, 0.0)                     # kill fully-masked rows
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _done():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)              # padded query rows
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, q_pos, kv_pos, *, window: Optional[int] = None,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 512, interpret: bool = False):
    """q: (b, s, nq, hd); k, v: (b, S, nkv, hd); positions as in ref.py."""
    b, s, nq, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    block_q = min(block_q, s)
    block_k = min(block_k, S)

    # pad sequence dims to block multiples; padding has position -1
    def pad_to(x, m, axis, value=0):
        r = (-x.shape[axis]) % m
        if r == 0:
            return x
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, r)
        return jnp.pad(x, pads, constant_values=value)

    qt = pad_to(jnp.moveaxis(q, 2, 1), block_q, 2)   # (b, nq, s', hd)
    kt = pad_to(jnp.moveaxis(k, 2, 1), block_k, 2)   # (b, nkv, S', hd)
    vt = pad_to(jnp.moveaxis(v, 2, 1), block_k, 2)
    qp = pad_to(q_pos, block_q, 1, -1)
    kp = pad_to(kv_pos, block_k, 1, -1)
    sp, Sp = qt.shape[2], kt.shape[2]
    ni, nk = sp // block_q, Sp // block_k

    grid = (b, nq, ni, nk)
    kern = functools.partial(_kernel, scale=hd ** -0.5, causal=causal,
                             window=window, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda bi, h, i, j: (bi, i)),
            pl.BlockSpec((1, block_k), lambda bi, h, i, j: (bi, j)),
            pl.BlockSpec((1, 1, block_q, hd), lambda bi, h, i, j: (bi, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, h, i, j: (bi, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, h, i, j: (bi, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bi, h, i, j: (bi, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq, sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, qt, kt, vt)
    return jnp.moveaxis(out[:, :, :s], 1, 2)         # (b, s, nq, hd)

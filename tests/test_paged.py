"""Paged KV-cache subsystem: pool allocator, Pallas paged decode
attention vs. oracle, paged engine parity with dense, and the O(pages)
P->D insert path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.paged_decode_attention import (paged_decode_attention,
                                                 paged_decode_attention_ref)
from repro.serving.kv_pool import PagePool, pages_for

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# page pool allocator
# ---------------------------------------------------------------------------

def test_page_pool_alloc_free_cycle():
    pool = PagePool(9, page_size=16)
    assert pool.n_free == 8
    a = pool.alloc(3)
    b = pool.alloc(2)
    assert pool.n_used == 5
    assert 0 not in set(a) | set(b)          # trash page never handed out
    assert len(set(a) | set(b)) == 5         # all distinct
    pool.free(a)
    c = pool.alloc(6)
    assert pool.n_free == 0
    assert len(set(c) | set(b)) == 8


def test_page_pool_exhaustion_and_misuse():
    pool = PagePool(4, page_size=8)
    ids = pool.alloc(3)
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(1)
    with pytest.raises(ValueError, match="trash"):
        pool.free([0])
    pool.free(ids)
    with pytest.raises(ValueError, match="double free"):
        pool.free([int(ids[0])])
    with pytest.raises(ValueError):
        PagePool(1, page_size=8)


def test_pages_for():
    assert pages_for(1, 16) == 1
    assert pages_for(16, 16) == 1
    assert pages_for(17, 16) == 2
    assert pages_for(0, 16) == 1             # even empty requests hold a page


# ---------------------------------------------------------------------------
# paged decode attention: ref vs dense oracle, kernel vs ref
# ---------------------------------------------------------------------------

def _paged_case(b, page, max_pages, nkv, hd, seed=0, dtype=jnp.float32):
    """Random pool + block tables + ragged lengths (>=1 per slot)."""
    n_pages = b * max_pages + 1
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 2)
    k_pool = jax.random.normal(ks[0], (n_pages, page, nkv, hd), dtype)
    v_pool = jax.random.normal(ks[1], (n_pages, page, nkv, hd), dtype)
    rng = np.random.RandomState(seed)
    tbl = np.zeros((b, max_pages), np.int32)
    lens = np.array([rng.randint(1, max_pages * page + 1) for _ in range(b)],
                    np.int32)
    free = list(range(1, n_pages))
    rng.shuffle(free)                         # non-contiguous physical pages
    for i in range(b):
        for j in range(pages_for(int(lens[i]), page)):
            tbl[i, j] = free.pop()
    return k_pool, v_pool, jnp.asarray(tbl), jnp.asarray(lens)


def test_paged_ref_equals_dense_ref():
    """With an identity block table the paged oracle IS the dense one."""
    b, page, max_pages, nq, nkv, hd = 2, 8, 4, 4, 2, 32
    S = page * max_pages
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, nq, hd))
    k = jax.random.normal(ks[1], (b, S, nkv, hd))
    v = jax.random.normal(ks[2], (b, S, nkv, hd))
    lens = jnp.asarray([S - 3, 17], jnp.int32)
    # pack the dense caches into a pool: slot i's pages are contiguous
    k_pool = jnp.concatenate(
        [jnp.zeros((1, page, nkv, hd)), k.reshape(b * max_pages, page, nkv, hd)])
    v_pool = jnp.concatenate(
        [jnp.zeros((1, page, nkv, hd)), v.reshape(b * max_pages, page, nkv, hd)])
    tbl = (jnp.arange(b * max_pages, dtype=jnp.int32).reshape(b, max_pages)
           + 1)
    out = paged_decode_attention_ref(q, k_pool, v_pool, tbl, lens)
    pos = jnp.arange(S, dtype=jnp.int32)[None]
    kv_pos = jnp.where(pos < lens[:, None], pos, -1)
    ref = decode_attention_ref(q, k, v, lens - 1, kv_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


PAGED_CASES = [
    # b, page, max_pages, nq, nkv, hd, window
    (2, 16, 4, 4, 2, 64, None),              # GQA g=2
    (3, 8, 6, 8, 1, 32, None),               # MQA g=8, ragged
    (2, 16, 8, 4, 4, 64, 20),                # MHA + sliding window
    (1, 32, 3, 6, 2, 128, None),             # big page, odd group g=3
    (2, 8, 5, 8, 2, 64, 12),                 # GQA + window < page span
]


@pytest.mark.parametrize("case", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_kernel_matches_ref(case, dtype):
    b, page, max_pages, nq, nkv, hd, win = case
    k_pool, v_pool, tbl, lens = _paged_case(b, page, max_pages, nkv, hd,
                                            seed=hash(case) % 1000,
                                            dtype=dtype)
    q = jax.random.normal(jax.random.fold_in(KEY, 7), (b, nq, hd), dtype)
    out = paged_decode_attention(q, k_pool, v_pool, tbl, lens, window=win,
                                 interpret=True)
    ref = paged_decode_attention_ref(q, k_pool, v_pool, tbl, lens, window=win)
    tol = dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


def test_paged_kernel_page_boundary_lengths():
    """Exact page-multiple lengths (the off-by-one hot spot)."""
    b, page, max_pages, nq, nkv, hd = 3, 8, 4, 4, 2, 32
    k_pool, v_pool, tbl, _ = _paged_case(b, page, max_pages, nkv, hd, seed=3)
    q = jax.random.normal(jax.random.fold_in(KEY, 9), (b, nq, hd))
    for lens in ([page, 2 * page, max_pages * page], [1, page + 1, page - 1]):
        lens = jnp.asarray(lens, jnp.int32)
        out = paged_decode_attention(q, k_pool, v_pool, tbl, lens,
                                     interpret=True)
        ref = paged_decode_attention_ref(q, k_pool, v_pool, tbl, lens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# paged engine: parity with dense, zero-copy insert, page accounting
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smollm():
    from repro.models.model import init_params
    cfg = get_config("smollm-135m").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def test_paged_engine_matches_dense(smollm):
    from repro.serving.engine import Engine
    from repro.serving.request import Request
    cfg, params = smollm
    dense = Engine(cfg, params, max_batch=2, max_len=48)
    paged = Engine(cfg, params, max_batch=2, max_len=48, paged=True,
                   page_size=8)
    for wave in range(2):
        outs = []
        for eng in (dense, paged):
            reqs = [Request(prompt_tokens=[5 + wave, 6, 7],
                            max_new_tokens=5) for _ in range(2)]
            for r in reqs:
                first, payload = eng.prefill_request(r)
                eng.insert(r, payload, first)
            while eng.n_active:
                eng.decode_step()
            outs.append([r.output_tokens for r in reqs])
        assert outs[0] == outs[1]
    # fused-engine insert is a block-table handoff: zero KV bytes moved
    assert paged.kv_insert_bytes_total == 0
    assert dense.kv_insert_bytes_total > 0
    # all pages reclaimed after the requests completed
    assert paged.pool.n_free == paged.pool.n_pages - 1
    assert paged.free_slots() == [0, 1]
    paged.assert_no_page_leaks()


def test_paged_engine_grows_pages_across_boundaries(smollm):
    """Decode past several page boundaries allocates pages on the fly."""
    from repro.serving.engine import Engine
    from repro.serving.request import Request
    cfg, params = smollm
    eng = Engine(cfg, params, max_batch=1, max_len=32, paged=True,
                 page_size=4)
    req = Request(prompt_tokens=[3, 4, 5], max_new_tokens=12)
    first, payload = eng.prefill_request(req)
    eng.insert(req, payload, first)
    assert len(eng._slot_pages[0]) == 1       # 3 tokens -> 1 page of 4
    while eng.n_active:
        eng.decode_step()
    assert len(req.output_tokens) == 12
    assert eng.pool.n_free == eng.pool.n_pages - 1
    eng.assert_no_page_leaks()


def test_paged_insert_bytes_ratio_acceptance(smollm):
    """Acceptance: per-insert KV bytes >=4x smaller than dense at
    max_batch=4, max_len=128, prompt=8 (page 16 -> one page vs 128)."""
    from repro.serving.engine import Engine
    from repro.serving.request import Request
    cfg, params = smollm
    dense = Engine(cfg, params, max_batch=4, max_len=128)
    paged = Engine(cfg, params, max_batch=4, max_len=128, paged=True,
                   page_size=16)
    cluster_src = Engine(cfg, params, max_batch=1, max_len=128, paged=True,
                         page_size=16)
    req = Request(prompt_tokens=list(range(2, 10)), max_new_tokens=2)
    first, payload = dense.prefill_request(req)
    dense.insert(req, payload, first)
    req2 = Request(prompt_tokens=list(range(2, 10)), max_new_tokens=2)
    first2, payload2 = cluster_src.prefill_request(req2)
    # prompt 8 @ page 16 is exactly one page (insert neutralizes the
    # payload's page list, so snapshot before)
    assert payload2.n_pages == 1
    paged.insert(req2, payload2, first2)      # cross-engine: O(pages) copy
    assert paged.kv_insert_bytes > 0
    ratio = dense.kv_insert_bytes / paged.kv_insert_bytes
    assert ratio >= 4.0, f"insert bytes ratio {ratio:.1f} < 4"
    # cross-engine insert drained the source pool; dest holds slot pages
    cluster_src.assert_no_page_leaks()
    paged.assert_no_page_leaks()


def test_paged_cluster_e2e_whisper():
    """Enc-dec arch through the paged disaggregated pipeline: cross-KV
    and lengths ride the side-state insert; attention KV moves by page."""
    from repro.core.cluster import EPDCluster
    from repro.models.model import init_params
    from repro.serving.request import Request
    cfg = get_config("whisper-base").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    cluster = EPDCluster(cfg, params, max_batch=2, max_len=48, paged=True,
                         page_size=8)
    reqs = [Request(prompt_tokens=[1, 2, 3], max_new_tokens=4,
                    mm_payload=b"audio-%d" % i, mm_tokens=0)
            for i in range(3)]
    for r in reqs:
        cluster.submit(r)
    done = cluster.run_until_done()
    assert len(done) == 3
    assert all(len(r.output_tokens) == 4 for r in done)
    # the decode engine imported pages (cross-engine), never whole caches
    assert cluster.decode_engine.kv_insert_bytes_total > 0
    page_layer = cluster.cost.kv_page_bytes_per_layer()
    assert page_layer > 0
    for p in cluster.report.kv_plans:
        for g in p.groups:
            assert g.nbytes % page_layer == pytest.approx(0.0, abs=1e-6)
        # rounding to pages must not inflate the payload by more than
        # one page slice per layer (guards the per-layer quantum)
        payload = sum(g.nbytes for g in p.groups)
        raw = cluster.decode_engine.kv_insert_bytes
        assert payload < raw + cfg.n_layers * page_layer + 1
    # both pools drained back to empty
    assert cluster.prefill_engine.pool.n_used == 0
    assert cluster.decode_engine.pool.n_used == 0
    cluster.prefill_engine.assert_no_page_leaks()
    cluster.decode_engine.assert_no_page_leaks()


def test_paged_cache_pytree_shapes(smollm):
    from repro.models.transformer import make_caches
    cfg, _ = smollm
    c = make_caches(cfg, 4, 64, dtype=jnp.float32, layout="paged",
                    page_size=16, n_pages=10)
    assert c["pages"].shape == (4, 4)
    for e in c["attn"]:
        if e is None:
            continue
        assert e.k.shape[1:3] == (10, 16)
        assert e.k.shape[0] == cfg.n_repeats
    with pytest.raises(ValueError, match="multiple"):
        make_caches(cfg, 4, 60, layout="paged", page_size=16, n_pages=10)
    with pytest.raises(ValueError, match="n_pages"):
        make_caches(cfg, 4, 64, layout="paged", page_size=16, n_pages=1)


def test_paged_insert_failure_keeps_payload_retryable(smollm):
    """A full engine rejects insert without touching the payload; the
    payload can be inserted later or explicitly released."""
    from repro.serving.engine import Engine
    from repro.serving.request import Request
    cfg, params = smollm
    eng = Engine(cfg, params, max_batch=1, max_len=32, paged=True,
                 page_size=8)
    r1 = Request(prompt_tokens=[3, 4, 5], max_new_tokens=20)
    f1, p1 = eng.prefill_request(r1)
    eng.insert(r1, p1, f1)
    r2 = Request(prompt_tokens=[6, 7], max_new_tokens=2)
    f2, p2 = eng.prefill_request(r2)
    used = eng.pool.n_used
    with pytest.raises(RuntimeError, match="no free decode slot"):
        eng.insert(r2, p2, f2)
    assert eng.pool.n_used == used            # nothing mutated
    eng.decode_step()                         # drain slot 0 eventually
    while eng.n_active:
        eng.decode_step()
    eng.insert(r2, p2, f2)                    # retry succeeds
    # the payload's refs now belong to the slot: a stray release is a
    # no-op instead of freeing pages out from under the live request
    eng.release_payload(p2)
    eng.assert_no_page_leaks()
    while eng.n_active:
        eng.decode_step()
    assert len(r2.output_tokens) >= 2
    # abandoning a payload returns its pages (and is idempotent)
    r3 = Request(prompt_tokens=[8, 9], max_new_tokens=2)
    _, p3 = eng.prefill_request(r3)
    assert eng.pool.n_used == p3.n_pages
    eng.assert_no_page_leaks(extra_holders=[p3.page_ids])
    eng.release_payload(p3)
    eng.release_payload(p3)
    assert eng.pool.n_used == 0
    eng.assert_no_page_leaks()


def test_paged_grow_pages_exhaustion_is_atomic(smollm):
    """Pool exhaustion mid-decode must not desync host/device tables:
    after the error, freeing capacity lets decode continue correctly."""
    from repro.serving.engine import Engine
    from repro.serving.request import Request
    cfg, params = smollm
    # 2 slots x 2 pages prompts fit, but growth beyond has no headroom
    eng = Engine(cfg, params, max_batch=2, max_len=32, paged=True,
                 page_size=8, n_pool_pages=5)   # 4 usable pages
    reqs = [Request(prompt_tokens=list(range(2, 18)), max_new_tokens=30)
            for _ in range(2)]                  # 16 tokens = 2 pages each
    for r in reqs:
        f, p = eng.prefill_request(r)
        eng.insert(r, p, f)
    with pytest.raises(RuntimeError, match="exhausted"):
        while eng.n_active:
            eng.decode_step()
    snapshot = [None if p is None else list(p) for p in eng._slot_pages]
    # host bookkeeping must agree with pool accounting after the error
    assert sum(len(p) for p in snapshot if p) == eng.pool.n_used
    # free one slot's pages (simulated preemption) and decode proceeds
    victim = next(i for i, r in enumerate(eng.slots) if r is not None)
    eng.slots[victim] = None
    eng._release_slot(victim)
    for _ in range(8):
        if not eng.n_active:
            break
        eng.decode_step()
    assert eng.pool.n_used <= eng.pool.n_pages - 1


# ---------------------------------------------------------------------------
# dense insert edge cases (satellite): dtype cast + seq pad
# ---------------------------------------------------------------------------

def test_dense_insert_dtype_cast_and_seq_pad(smollm):
    """P engine at a shorter max_len / wider dtype than the D engine:
    insert must pad the sequence dim (kv_pos with -1) and cast KV."""
    from repro.models.transformer import make_caches
    from repro.serving.steps import make_insert_fn
    cfg, _ = smollm
    src = make_caches(cfg, 1, 16, dtype=jnp.float32)
    # fill src with recognizable values
    src["attn"] = tuple(
        type(e)(jnp.ones_like(e.k), jnp.full_like(e.v, 2.0),
                jnp.zeros_like(e.kv_pos)) if e is not None else None
        for e in src["attn"])
    src["len"] = jnp.asarray([7], jnp.int32)
    dst = make_caches(cfg, 3, 32, dtype=jnp.float32, kv_dtype=jnp.bfloat16)
    out = make_insert_fn(cfg)(src, dst, 1)
    e = out["attn"][0]
    assert e.k.dtype == jnp.bfloat16                       # cast applied
    np.testing.assert_array_equal(np.asarray(e.k[:, 1, :16]), 1.0)
    np.testing.assert_array_equal(np.asarray(e.k[:, 1, 16:]), 0.0)  # pad
    np.testing.assert_array_equal(np.asarray(e.kv_pos[:, 1, 16:]), -1)
    np.testing.assert_array_equal(np.asarray(e.kv_pos[:, 1, :16]), 0)
    assert int(out["len"][1]) == 7
    # untouched slots stay zero
    np.testing.assert_array_equal(np.asarray(out["attn"][0].k[:, 0]), 0.0)

"""Fault-tolerant continuous batching: the chaos layer composed with
the iteration-level scheduler (EPDCluster.run_continuous + FaultPlan).

The hard constraint under test: for ANY seeded fault plan, every
request that completes produces greedy outputs BIT-IDENTICAL to the
zero-fault continuous run, and ``report.lost`` is the only other exit —
no silent drops, no leaked pages, no dangling accountant records.
Recovery never re-executes a sampled token (re-prefill replays
``prompt + output[:-1]`` through the same jitted forward), so
scheduling order under chaos cannot change greedy outputs.

Matrix: {wire loss, mid-run decode crash, swap loss} x
{paged, prefix_cache, chunked}, with per-iteration page-leak audits via
the ``on_step`` hook, plus the recovery=False loss baseline and a
conservation property (hypothesis when available, seeded fallback
always).
"""
import jax
import pytest

from repro.configs import get_config
from repro.core.batching import IterationScheduler, PrefillJob
from repro.core.cluster import EPDCluster
from repro.core.faults import (SITE_STORE_FETCH, SITE_SWAP_IN,
                               SITE_TRANSFER_HANDSHAKE, SITE_TRANSFER_WIRE,
                               ArmedFault, FaultPlan, RetryPolicy)
from repro.models.model import init_params
from repro.serving.request import Request


@pytest.fixture(scope="module")
def smollm():
    cfg = get_config("smollm-135m").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def llava():
    cfg = get_config("llava-next-mistral-7b").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _text_reqs(n=4, m=8):
    return [Request(prompt_tokens=list(range(3 + i, 20 + i)),
                    max_new_tokens=m) for i in range(n)]


def _audit(cl):
    """Page-leak audit at an iteration boundary: the prefill engine
    (whose pool also backs ready-but-unadmitted payloads through the
    shared scheduler reference) and every live decode engine."""
    cl.prefill_engine.assert_no_page_leaks()
    for i in cl.live_decode_indices():
        cl.decode_engines[i].assert_no_page_leaks()


def _conserved(cl):
    """Post-drain conservation: router pending ledgers back to zero on
    every live instance, pools balanced, accountant fully closed."""
    for name, st in cl.router.status.items():
        if st.down:
            continue
        assert st.pending_tokens == 0.0, name
        assert st.pending_by_req == {}, name
    _audit(cl)
    cl.acc.assert_all_closed()


# ---------------------------------------------------------------------------
# chaos matrix: wire loss + mid-run decode crash x engine configs
# ---------------------------------------------------------------------------

MODES = {
    "paged": dict(paged=True, page_size=8),
    "prefix_cache": dict(paged=True, page_size=8, prefix_cache=True,
                         chunked_prefill=True, prefill_chunk=16),
    "chunked": dict(paged=True, page_size=8, chunked_prefill=True,
                    prefill_chunk=16),
}


@pytest.mark.parametrize("mode", sorted(MODES))
def test_chaos_matrix_bit_identical(smollm, mode):
    cfg, params = smollm
    kw = dict(max_batch=2, max_len=64, n_decode=2, **MODES[mode])

    ref = _text_reqs()
    c0 = EPDCluster(cfg, params, **kw)
    c0.run_continuous(ref)
    zero = [r.output_tokens for r in ref]

    plan = FaultPlan(seed=1,
                     rates={SITE_TRANSFER_WIRE: 0.3,
                            SITE_TRANSFER_HANDSHAKE: 0.2},
                     armed=[ArmedFault("decode.crash", key=(0, 5))])
    reqs = _text_reqs()
    cl = EPDCluster(cfg, params, faults=plan, **kw)
    done = cl.run_continuous(reqs, on_step=lambda step: _audit(cl))

    assert cl.report.instance_crashes == 1
    assert not cl.report.lost and len(done) == len(reqs)
    assert [r.output_tokens for r in reqs] == zero
    _conserved(cl)


def test_mid_flight_crash_harvests_onto_survivor(smollm):
    """A decode crash with requests actively decoding: the in-flight
    work re-enters the scheduler as re-prefill jobs routed to the
    survivor — no global drain, outputs bit-identical."""
    cfg, params = smollm
    kw = dict(max_batch=2, max_len=64, paged=True, page_size=8,
              prefix_cache=True, chunked_prefill=True, prefill_chunk=16,
              n_decode=2)
    ref = _text_reqs()
    EPDCluster(cfg, params, **kw).run_continuous(ref)

    plan = FaultPlan(seed=1, armed=[ArmedFault("decode.crash",
                                               key=(0, 8))])
    reqs = _text_reqs()
    cl = EPDCluster(cfg, params, faults=plan, **kw)
    done = cl.run_continuous(reqs, on_step=lambda step: _audit(cl))
    assert cl.report.instance_crashes == 1
    assert cl.report.reroutes >= 1
    assert cl.metrics.total("continuous_reroute_jobs_total") >= 1
    assert not cl.report.lost and len(done) == len(reqs)
    assert [r.output_tokens for r in reqs] == \
        [r.output_tokens for r in ref]
    _conserved(cl)


def test_wire_loss_heals_via_retry_park(smollm):
    """Transfer faults during admission park the job with a retry_at
    clock (scheduler-visible, non-blocking) instead of spinning inside
    the admission step; the backoff lands in telemetry as retry time."""
    cfg, params = smollm
    kw = dict(max_batch=2, max_len=64, paged=True, page_size=8,
              prefix_cache=True, chunked_prefill=True, prefill_chunk=16)
    ref = _text_reqs()
    EPDCluster(cfg, params, **kw).run_continuous(ref)

    plan = FaultPlan(seed=7, rates={SITE_TRANSFER_WIRE: 0.5})
    reqs = _text_reqs()
    cl = EPDCluster(cfg, params, faults=plan, **kw)
    done = cl.run_continuous(reqs, on_step=lambda step: _audit(cl))
    assert len(done) == len(reqs) and not cl.report.lost
    assert [r.output_tokens for r in reqs] == \
        [r.output_tokens for r in ref]
    if cl.metrics.total("sched_retry_parks_total"):
        assert cl.metrics.total("retry_time_seconds_total") > 0
        assert cl.report.retry_time_total > 0
    _conserved(cl)


def test_swap_loss_chaos_recomputes_bit_identical(smollm):
    """Armed swap-in loss under decode-pool pressure: the engine's §3.2
    recompute arm rebuilds the lost KV; continuous outputs stay
    bit-identical and every page balances each iteration."""
    cfg, params = smollm
    kw = dict(max_batch=3, max_len=64, paged=True, page_size=4,
              preemption=True, n_decode_pool_pages=14,
              chunked_prefill=True, prefill_chunk=16)

    def reqs():
        return [Request(prompt_tokens=list(range(3 + i, 19 + i)),
                        max_new_tokens=12) for i in range(4)]

    ref = reqs()
    EPDCluster(cfg, params, **kw).run_continuous(ref)
    zero = [r.output_tokens for r in ref]

    plan = FaultPlan(seed=3, armed=[ArmedFault(SITE_SWAP_IN)])
    rs = reqs()
    cl = EPDCluster(cfg, params, faults=plan, **kw)
    done = cl.run_continuous(rs, on_step=lambda step: _audit(cl))
    assert cl.report.swap_losses == 1
    assert not cl.report.lost and len(done) == len(rs)
    assert [r.output_tokens for r in rs] == zero
    _conserved(cl)


def test_store_fetch_chaos_takes_recompute_arm(llava):
    """Store fetch faults inside the continuous loop: retries push the
    job's own barrier clock; exhaustion takes the §3.2 recompute arm as
    an encode work item — bit-identical either way."""
    cfg, params = llava
    kw = dict(max_batch=2, max_len=64, paged=True, page_size=8,
              chunked_prefill=True, prefill_chunk=16, ep_overlap="async")

    def reqs():
        return [Request(prompt_tokens=list(range(1, 18)), max_new_tokens=6,
                        mm_payload=b"imgA", mm_tokens=8, mm_pos=4),
                Request(prompt_tokens=list(range(3, 25)), max_new_tokens=6),
                Request(prompt_tokens=list(range(2, 20)), max_new_tokens=6,
                        mm_payload=b"imgB", mm_tokens=8, mm_pos=2)]

    ref = reqs()
    EPDCluster(cfg, params, **kw).run_continuous(ref)
    zero = [r.output_tokens for r in ref]

    # rate 1.0: every fetch fails, every policy exhausts -> recompute
    plan = FaultPlan(seed=2, rates={SITE_STORE_FETCH: 1.0})
    rs = reqs()
    cl = EPDCluster(cfg, params, faults=plan, **kw)
    done = cl.run_continuous(rs, on_step=lambda step: _audit(cl))
    assert cl.report.recomputes == 2          # one per distinct image
    assert cl.report.store_retries >= 1
    assert cl.metrics.total("continuous_recomputes_total") == 2
    assert not cl.report.lost and len(done) == len(rs)
    assert [r.output_tokens for r in rs] == zero
    _conserved(cl)


# ---------------------------------------------------------------------------
# engine.lost drain: revival with recovery, surfaced without
# ---------------------------------------------------------------------------

def _swap_kill_run(cfg, params, recovery):
    """Drive the engine-kill path deterministically: mid-run, preempt a
    multimodal decode slot and arm a swap-in loss — the engine cannot
    recompute a scattered multimodal suffix in place, so it kills the
    request into ``engine.lost``. The cluster harvest decides its fate."""
    kw = dict(max_batch=3, max_len=64, paged=True, page_size=4,
              preemption=True, chunked_prefill=True, prefill_chunk=16,
              ep_overlap="async")
    reqs = [Request(prompt_tokens=list(range(1, 18)), max_new_tokens=10,
                    mm_payload=b"imgA", mm_tokens=8, mm_pos=4),
            Request(prompt_tokens=list(range(3, 25)), max_new_tokens=10),
            Request(prompt_tokens=list(range(2, 20)), max_new_tokens=10,
                    mm_payload=b"imgB", mm_tokens=8, mm_pos=2)]
    cl = EPDCluster(cfg, params, faults=FaultPlan(seed=5),
                    recovery=recovery, **kw)
    state = {"fired": False}

    def chaos(step):
        _audit(cl)
        if state["fired"] or step < 6:
            return
        for eng in cl.decode_engines:
            for i, s in enumerate(eng.slots):
                if s is not None and s.is_multimodal and s.output_tokens:
                    eng.preempt_slot(i)
                    cl.injector.arm(SITE_SWAP_IN)
                    state["fired"] = True
                    return

    done = cl.run_continuous(reqs, on_step=chaos)
    assert state["fired"]
    # the loop drained engine.lost either way — nothing lingers there
    assert all(not e.lost for e in cl.decode_engines)
    return cl, reqs, done


def test_engine_kill_revived_bit_identical(llava):
    cfg, params = llava
    ref = [Request(prompt_tokens=list(range(1, 18)), max_new_tokens=10,
                   mm_payload=b"imgA", mm_tokens=8, mm_pos=4),
           Request(prompt_tokens=list(range(3, 25)), max_new_tokens=10),
           Request(prompt_tokens=list(range(2, 20)), max_new_tokens=10,
                   mm_payload=b"imgB", mm_tokens=8, mm_pos=2)]
    EPDCluster(cfg, params, max_batch=3, max_len=64, paged=True,
               page_size=4, preemption=True, chunked_prefill=True,
               prefill_chunk=16, ep_overlap="async").run_continuous(ref)

    cl, reqs, done = _swap_kill_run(cfg, params, recovery=True)
    assert not cl.report.lost and len(done) == len(reqs)
    assert cl.report.reroutes >= 1
    assert cl.metrics.total("continuous_harvests_total") >= 1
    assert [r.output_tokens for r in reqs] == \
        [r.output_tokens for r in ref]
    _conserved(cl)


def test_engine_kill_surfaces_lost_when_recovery_off(llava):
    cfg, params = llava
    cl, reqs, done = _swap_kill_run(cfg, params, recovery=False)
    assert len(cl.report.lost) == 1
    assert all(r.killed for r in cl.report.lost)
    assert len(done) + len(cl.report.lost) == len(reqs)
    # the accountant record of the lost request was closed, not leaked
    cl.acc.assert_all_closed()
    _audit(cl)


def test_crash_recovery_off_reproduces_loss_baseline(smollm):
    cfg, params = smollm
    plan = FaultPlan(seed=1, armed=[ArmedFault("decode.crash",
                                               key=(0, 5))])
    reqs = _text_reqs()
    cl = EPDCluster(cfg, params, max_batch=2, max_len=64, paged=True,
                    page_size=8, chunked_prefill=True, prefill_chunk=16,
                    n_decode=2, faults=plan, recovery=False)
    done = cl.run_continuous(reqs)
    assert cl.report.instance_crashes == 1
    assert len(cl.report.lost) >= 1
    assert all(r.killed for r in cl.report.lost)
    assert len(done) + len(cl.report.lost) == len(reqs)
    cl.acc.assert_all_closed()


# ---------------------------------------------------------------------------
# whisper-class (encoder-decoder) requests as monolithic jobs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [True, False])
def test_whisper_continuous_matches_serial(paged):
    """Enc-dec requests cannot run the chunked state machine; the
    scheduler serves them as single-chunk (monolithic) prefill jobs —
    same outputs as the serial driver, paged or dense."""
    cfg = get_config("whisper-base").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    kw = dict(max_batch=2, max_len=48)
    if paged:
        kw.update(paged=True, page_size=8)

    def reqs():
        return [Request(prompt_tokens=[1, 2, 3], max_new_tokens=4,
                        mm_payload=b"audio-%d" % i, mm_tokens=0)
                for i in range(3)]

    c0 = EPDCluster(cfg, params, **kw)
    rs = reqs()
    for r in rs:
        c0.submit(r)
    c0.run_until_done()
    serial = [r.output_tokens for r in rs]

    c1 = EPDCluster(cfg, params, **kw)
    rs2 = reqs()
    done = c1.run_continuous(rs2)
    assert [r.output_tokens for r in rs2] == serial
    assert len(done) == len(rs2) and not c1.report.lost
    if paged:
        _audit(c1)
    c1.acc.assert_all_closed()


# ---------------------------------------------------------------------------
# scheduler units: retry_at parking, adaptive chunk budget
# ---------------------------------------------------------------------------

def _job(n_tokens=32, chunk=16, **kw):
    return PrefillJob(req=Request(prompt_tokens=list(range(n_tokens)),
                                  max_new_tokens=4),
                      n_tokens=n_tokens, chunk=chunk, **kw)


def test_park_ready_allows_overtaking():
    s = IterationScheduler(max_live_prefills=2)
    a, b = _job(), _job()
    for j in (a, b):
        s.submit(j)
    s.plan(now=0.0)                            # promote both to live
    for j in (a, b):
        j.progress = j.n_tokens
        j.result = ("first", "payload")
        s.mark_ready(j)
    # a failed admission: parked at the queue head with a future clock
    a2 = s.ready.popleft()
    assert a2 is a
    s.park_ready(a, retry_at=5.0)
    plan = s.plan(now=0.0, free_slots=2)
    assert plan.admit == [b]                   # b overtakes the parked a
    assert (a, "retry_wait") in plan.stalled
    assert s.next_barrier_time() == 5.0        # idle-jump target
    plan = s.plan(now=6.0, free_slots=2)
    assert plan.admit == [a]


def test_elapsed_barrier_does_not_mask_parked_retry_at():
    # livelock regression: a pool-stalled live job whose barrier is in
    # the PAST must not drag the idle-jump target below a parked ready
    # job's future retry_at — the jump is what matures the retry and
    # releases the parked payload's pool pages
    s = IterationScheduler(max_live_prefills=2)
    stalled, parked = _job(), _job()
    for j in (stalled, parked):
        s.submit(j)
    s.plan(now=0.0)                            # promote both to live
    parked.progress = parked.n_tokens
    parked.result = ("first", "payload")
    s.mark_ready(parked)
    s.park_ready(parked, retry_at=7.0)
    # `stalled` has no future barrier (barrier_time() <= now): the raw
    # min is its elapsed barrier, the filtered min is the retry clock
    assert stalled.barrier_time() <= 3.0
    assert s.next_barrier_time() == stalled.barrier_time()
    assert s.next_barrier_time(after=3.0) == 7.0
    assert s.next_barrier_time(after=7.0) is None


def test_retry_policy_next_retry_at():
    p = RetryPolicy(max_attempts=3)
    t1 = p.next_retry_at(10.0, 1, key="k")
    t2 = p.next_retry_at(10.0, 2, key="k")
    assert t1 > 10.0 and t2 > 10.0
    assert p.next_retry_at(10.0, 3, key="k") is None      # exhausted
    # deterministic: same (attempt, key) -> same clock
    assert p.next_retry_at(10.0, 1, key="k") == t1


def test_adaptive_budget_shrinks_and_grows():
    s = IterationScheduler(max_live_prefills=2, chunk_budget_tokens=64,
                           adaptive_chunking=True, min_chunk_budget=16)
    j = s.submit(_job(n_tokens=128, chunk=32))
    r = s.submit(_job(n_tokens=32, chunk=32))
    s.plan(now=0.0)                            # promote both to live
    r.progress = r.n_tokens
    r.result = ("first", "payload")
    s.mark_ready(r)
    # decode slots starved (free_slots=0) with a ready backlog: shrink
    p = s.plan(now=0.0, free_slots=0)
    assert s.budget_shrinks == 1 and s._budget == 32
    assert p.chunks == [j]                     # prefill keeps moving
    s.plan(now=0.0, free_slots=0)
    assert s.budget_shrinks == 2 and s._budget == 16   # at the floor
    s.plan(now=0.0, free_slots=0)
    assert s.budget_shrinks == 2               # clamped at the floor
    # backlog admitted, slots free: grow back
    s.plan(now=0.0, free_slots=2)
    assert s.budget_grows == 1 and s._budget == 32


def test_adaptive_budget_static_without_flag():
    s = IterationScheduler(max_live_prefills=2, chunk_budget_tokens=64)
    r = s.submit(_job(n_tokens=32, chunk=32))
    s.submit(_job(n_tokens=128, chunk=32))
    s.plan(now=0.0)                            # promote both to live
    r.progress = r.n_tokens
    r.result = ("first", "payload")
    s.mark_ready(r)
    for _ in range(3):
        s.plan(now=0.0, free_slots=0)
    assert s.budget_shrinks == 0 and s.budget_grows == 0
    assert s._budget == 64


def test_adaptive_chunking_cluster_bit_identical(smollm):
    cfg, params = smollm
    kw = dict(max_batch=2, max_len=64, paged=True, page_size=8,
              chunked_prefill=True, prefill_chunk=16, prefix_cache=True)
    prompts = [list(range(1, 30)), list(range(5, 17)), list(range(2, 50)),
               [7, 8, 9], list(range(2, 50)), list(range(40, 11, -1))]

    def reqs():
        return [Request(prompt_tokens=prompts[i % 6], max_new_tokens=10)
                for i in range(10)]

    c0 = EPDCluster(cfg, params, **kw)
    r0 = reqs()
    c0.run_continuous(r0, chunk_budget_tokens=48)
    fixed = [r.output_tokens for r in r0]

    c1 = EPDCluster(cfg, params, **kw)
    r1 = reqs()
    c1.run_continuous(r1, chunk_budget_tokens=48, adaptive_chunking=True)
    assert [r.output_tokens for r in r1] == fixed
    s = c1.continuous_scheduler
    assert s.budget_shrinks > 0                # decode-starved phases hit
    _audit(c1)


# ---------------------------------------------------------------------------
# conservation property: ledger and refcounts conserve to zero
# ---------------------------------------------------------------------------

def _conservation_run(smollm, seed, wire, shake, crash_step):
    cfg, params = smollm
    armed = ([ArmedFault("decode.crash", key=(0, crash_step))]
             if crash_step else [])
    plan = FaultPlan(seed=seed, rates={SITE_TRANSFER_WIRE: wire,
                                       SITE_TRANSFER_HANDSHAKE: shake},
                     armed=armed)
    reqs = _text_reqs()
    cl = EPDCluster(cfg, params, max_batch=2, max_len=64, paged=True,
                    page_size=8, prefix_cache=True, chunked_prefill=True,
                    prefill_chunk=16, n_decode=2, faults=plan)
    done = cl.run_continuous(reqs, on_step=lambda step: _audit(cl))
    assert len(done) + len(cl.report.lost) == len(reqs)
    _conserved(cl)


@pytest.mark.parametrize("seed,wire,shake,crash_step", [
    (0, 0.05, 0.0, 0), (1, 0.3, 0.2, 5), (2, 0.5, 0.0, 3),
    (3, 0.0, 0.5, 8), (4, 0.2, 0.2, 0),
])
def test_conservation_seeded(smollm, seed, wire, shake, crash_step):
    """Concrete seeded fallback (runs even without hypothesis): under
    arbitrary chaos the router's pending-token ledger and every pool
    refcount conserve back to zero and the accountant closes."""
    _conservation_run(smollm, seed, wire, shake, crash_step)


def test_conservation_property(smollm):
    hypothesis = pytest.importorskip("hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    from conftest import hyp_max_examples

    @settings(max_examples=hyp_max_examples(12), deadline=None)
    @given(st.integers(0, 2**31), st.sampled_from([0.0, 0.1, 0.3, 0.6]),
           st.sampled_from([0.0, 0.2, 0.4]), st.integers(0, 10))
    def prop(seed, wire, shake, crash_step):
        _conservation_run(smollm, seed, wire, shake, crash_step)

    prop()

"""mamba2-370m [ssm] — attention-free, SSD (state-space duality).

[arXiv:2405.21060] — 48 Mamba2 blocks (each block contains its own gated
projection, so there is no separate FFN: d_ff=0).
"""
from repro.configs.base import LayerSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    pattern=(LayerSpec("ssm", "none"),),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)

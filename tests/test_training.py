"""Training substrate: optimizer correctness + end-to-end learning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import init_params
from repro.training.data import synthetic_batches
from repro.training.optimizer import AdamW
from repro.training.train import make_train_step, train_loop


def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, warmup_steps=1)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip():
    opt = AdamW(lr=1e-3, grad_clip=1.0, warmup_steps=1)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e9)}
    p2, _ = opt.update(huge, state, params)
    assert float(jnp.abs(p2["w"]).max()) < 1.0   # step bounded by lr-ish


def test_smollm_learns_synthetic_task():
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batches = synthetic_batches(cfg, batch=8, seq=32, steps=30, seed=1)
    _, _, losses = train_loop(cfg, params, batches,
                              opt=AdamW(lr=3e-3, warmup_steps=10))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_microbatched_grads_match_full_batch():
    """Gradient accumulation must match the full-batch gradient.

    (Gradients, not post-Adam params: at step 1 Adam's update is ~sign(g),
    so params are discontinuous in g near zero — not a meaningful check.)
    """
    from repro.models.model import train_forward
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = next(synthetic_batches(cfg, batch=8, seq=16, steps=1, seed=2))

    def loss_fn(p, b):
        return train_forward(p, cfg, b, remat=False)[0]

    g_full = jax.grad(loss_fn)(params, batch)
    n = 4
    micro = jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)
    g_acc = jax.tree.map(jnp.zeros_like, params)
    losses = []
    for i in range(n):
        mb = jax.tree.map(lambda x: x[i], micro)
        l, g = jax.value_and_grad(loss_fn)(params, mb)
        losses.append(float(l))
        g_acc = jax.tree.map(lambda a, b: a + b / n, g_acc, g)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-3)


def test_remat_matches_no_remat():
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = next(synthetic_batches(cfg, batch=4, seq=16, steps=1, seed=3))
    opt = AdamW(lr=1e-3, warmup_steps=1)
    p_a, _, _ = jax.jit(make_train_step(cfg, opt, remat=False))(
        params, opt.init(params), batch)
    p_b, _, _ = jax.jit(make_train_step(cfg, opt, remat=True))(
        params, opt.init(params), batch)
    for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
        # remat re-runs the forward with a different reassociation order;
        # allow a couple of f32 ulps of drift on the updated params.
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-5, rtol=1e-5)

"""Jit'd public wrapper for the decode-attention kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import dispatch
from repro.kernels.decode_attention.kernel import decode_attention as _kernel
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(q, k, v, q_pos, kv_pos, *, window: Optional[int] = None,
                     block_k: int = 512, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = dispatch.interpret()
    return _kernel(q, k, v, q_pos, kv_pos, window=window, block_k=block_k,
                   interpret=interpret)


__all__ = ["decode_attention", "decode_attention_ref"]

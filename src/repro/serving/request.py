"""Request model shared by the real engine and the EPD simulator."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

_ids = itertools.count()


@dataclass
class Request:
    prompt_tokens: List[int]
    max_new_tokens: int = 64
    # multimodal payload: raw bytes standing in for an image/audio clip;
    # None => text-only request (takes the P-D path, paper §3.4)
    mm_payload: Optional[bytes] = None
    mm_tokens: int = 0                  # vision/audio token count
    # position of the image run within the combined sequence: the first
    # mm_pos entries of prompt_tokens precede the image tokens, the rest
    # follow (0 = image-first, the legacy prepend ordering)
    mm_pos: int = 0
    eos_token: int = -1                 # -1: never stop early
    # preemption: higher priority is preempted later; killed marks a
    # request dropped by the no-preemption OOM baseline; n_preempts
    # counts page-level preemptions (starvation-guard + metrics)
    priority: int = 0
    killed: bool = False
    n_preempts: int = 0
    request_id: int = field(default_factory=lambda: next(_ids))

    # lifecycle timestamps (simulation or wall-clock), seconds
    t_arrival: float = 0.0
    t_encode_start: float = -1.0
    t_encode_done: float = -1.0
    t_prefill_start: float = -1.0
    t_first_token: float = -1.0
    t_done: float = -1.0

    output_tokens: List[int] = field(default_factory=list)

    @property
    def is_multimodal(self) -> bool:
        return self.mm_payload is not None

    @property
    def total_prompt_len(self) -> int:
        return len(self.prompt_tokens) + self.mm_tokens

    # -- metrics ------------------------------------------------------------
    def stage_breakdown(self) -> dict:
        """Where the TTFT went: queueing/encode/dispatch/prefill (seconds).

        encode_queue covers arrival -> encode start (or prefill start for
        text-only); dispatch covers the E->P hand-off (store fetch +
        scheduling) for multimodal requests.
        """
        out = {}
        if self.is_multimodal and self.t_encode_start >= 0:
            out["encode_queue"] = self.t_encode_start - self.t_arrival
            out["encode"] = self.t_encode_done - self.t_encode_start
            out["dispatch"] = max(0.0, self.t_prefill_start
                                  - self.t_encode_done)
        else:
            out["encode_queue"] = 0.0
            out["encode"] = 0.0
            out["dispatch"] = max(0.0, self.t_prefill_start - self.t_arrival)
        out["prefill"] = self.t_first_token - self.t_prefill_start
        out["decode"] = max(0.0, self.t_done - self.t_first_token)
        return out

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_arrival

    @property
    def tpot(self) -> float:
        n = len(self.output_tokens)
        if n <= 1 or self.t_done < 0:
            return 0.0
        return (self.t_done - self.t_first_token) / (n - 1)

    def meets_slo(self, ttft_ms: float, tpot_ms: float) -> bool:
        return (self.ttft * 1e3 <= ttft_ms) and (self.tpot * 1e3 <= tpot_ms)

"""Beyond-paper benchmark extensions.

* ``store_capacity_study`` — MM Store hit rate & TTFT vs store capacity
  (the cache-sizing question the paper's Mooncake-backed store raises but
  does not answer).
* ``stage_breakdown`` — per-deployment TTFT decomposition (queue / encode
  / E->P dispatch / prefill): shows WHY each deployment wins or loses,
  not just that it does.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.configs import get_config
from repro.core.simulator import SHAREGPT_4O, SimConfig, Simulator, \
    gen_requests, simulate

MODEL = "openpangu-7b-vl"


def store_capacity_study() -> List[str]:
    model = get_config(MODEL)
    ds = dataclasses.replace(SHAREGPT_4O, unique_images=64)
    rows = ["store_capacity,capacity_features,hit_rate,ttft_ms"]
    from repro.core.costmodel import CostModel
    feat_bytes = int(CostModel(model).feature_bytes(644))
    for cap_features in (4, 16, 64, 0):          # 0 => unbounded
        cfg = SimConfig(deployment="E-P-D")
        sim = Simulator(model, cfg)
        if cap_features:
            sim.store.capacity = cap_features * feat_bytes
        reqs = gen_requests(ds, 256, rate=4.0, seed=17)
        m = sim.run(reqs)
        rows.append(f"store_capacity,{cap_features or 'inf'},"
                    f"{m.store_hit_rate:.3f},{m.mean_ttft_ms:.1f}")
    return rows


def stage_breakdown() -> List[str]:
    model = get_config(MODEL)
    rows = ["stage_breakdown,deployment,encode_queue_ms,encode_ms,"
            "dispatch_ms,prefill_ms"]
    for dep in ("TP1", "EP-D", "(E-P)-D", "(E-D)-P", "E-P-D"):
        m = simulate(model, dep, SHAREGPT_4O, rate=6.0, n_requests=192,
                     seed=23)
        b = m.stage_breakdown_ms()
        rows.append(f"stage_breakdown,{dep},{b['encode_queue']:.1f},"
                    f"{b['encode']:.1f},{b['dispatch']:.1f},"
                    f"{b['prefill']:.1f}")
    return rows


EXTENSION_BENCHMARKS = [store_capacity_study, stage_breakdown]

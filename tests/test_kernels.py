"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref)
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.ssd_scan import ssd_ref, ssd_scan, ssd_sequential

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # b, s, S, nq, nkv, hd, window, causal
    (2, 64, 64, 4, 2, 64, None, True),
    (1, 100, 100, 8, 8, 32, None, True),       # MHA, ragged seq
    (2, 128, 128, 4, 1, 64, 32, True),         # MQA + sliding window
    (1, 50, 70, 4, 2, 64, None, False),        # cross attention
    (1, 33, 33, 2, 2, 128, 16, True),          # odd seq, window
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, s, S, nq, nkv, hd, win, causal = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, nq, hd), dtype)
    k = jax.random.normal(ks[1], (b, S, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, S, nkv, hd), dtype)
    qp = jnp.broadcast_to(jnp.arange(s), (b, s))
    qp = jnp.where(qp < s - 3, qp, -1)          # padded queries
    kp = jnp.broadcast_to(jnp.arange(S), (b, S))
    out = flash_attention(q, k, v, qp, kp, window=win, causal=causal,
                          block_q=32, block_k=32)
    ref = attention_ref(q, k, v, qp, kp, window=win, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tol(dtype))


def test_attention_ref_chunked_equals_dense():
    """The q-chunked long-seq path must equal the dense path."""
    from repro.kernels.flash_attention import ref as R
    b, s, nq, nkv, hd = 1, 64, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, nq, hd))
    k = jax.random.normal(ks[1], (b, s, nkv, hd))
    v = jax.random.normal(ks[2], (b, s, nkv, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    dense = R._attention_dense(q, k, v, pos, pos, None, True)
    old_thr, old_chunk = R._CHUNK_THRESHOLD, R._Q_CHUNK
    try:
        R._CHUNK_THRESHOLD, R._Q_CHUNK = 16, 16
        chunked = R.attention_ref(q, k, v, pos, pos)
    finally:
        R._CHUNK_THRESHOLD, R._Q_CHUNK = old_thr, old_chunk
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (2, 64, 4, 2, 64, None),
    (1, 100, 8, 1, 32, None),                   # MQA, ragged cache
    (2, 48, 4, 4, 64, 16),                      # MHA + window
    (3, 37, 6, 2, 128, None),                   # odd sizes
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(case, dtype):
    b, S, nq, nkv, hd, win = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, nq, hd), dtype)
    k = jax.random.normal(ks[1], (b, S, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, S, nkv, hd), dtype)
    kp = jnp.broadcast_to(jnp.arange(S), (b, S))
    kp = jnp.where(kp < S - 5, kp, -1)           # empty ring slots
    qp = jnp.array([S - 6] * b)
    out = decode_attention(q, k, v, qp, kp, window=win, block_k=32)
    ref = decode_attention_ref(q, k, v, qp, kp, window=win)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tol(dtype))


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    (2, 64, 4, 32, 16, 16),
    (1, 128, 2, 64, 128, 32),
    (2, 96, 3, 32, 64, 32),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_kernel_matches_sequential(case):
    B, S, H, P, N, chunk = case
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    dsk = jnp.ones((H,))
    s0 = jax.random.normal(ks[5], (B, H, P, N)) * 0.1
    yk, fk = ssd_scan(x, dt, a, bm, cm, dsk, chunk, s0)
    yr, fr = ssd_ref(x, dt, a, bm, cm, dsk, chunk, s0)
    ys, fs = ssd_sequential(x, dt, a, bm, cm, dsk, s0)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(ys),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(fk), np.asarray(fs),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(ys),
                               atol=2e-3, rtol=2e-3)


def test_ssd_decode_step_continues_scan():
    """Chunked scan state + single-token updates == longer scan."""
    from repro.models.ssm import ssd_decode_step
    B, S, H, P, N = 1, 32, 2, 16, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S + 1, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S + 1, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    bm = jax.random.normal(ks[3], (B, S + 1, N)) * 0.3
    cm = jax.random.normal(ks[4], (B, S + 1, N)) * 0.3
    dsk = jnp.ones((H,))
    y_full, f_full = ssd_sequential(x, dt, a, bm, cm, dsk)
    _, f_prefix = ssd_ref(x[:, :S], dt[:, :S], a, bm[:, :S], cm[:, :S],
                          dsk, 16)
    y_step, f_step = ssd_decode_step(x[:, S], dt[:, S], a, bm[:, S],
                                     cm[:, S], dsk, f_prefix)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full[:, S]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f_step), np.asarray(f_full),
                               atol=1e-4, rtol=1e-4)

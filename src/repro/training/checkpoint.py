"""Checkpointing: save/restore params + optimizer state + step metadata.

Plain-numpy ``.npz`` per pytree (no orbax dependency), with a manifest
that records the flattened tree structure and a config fingerprint so a
restore into the wrong architecture fails loudly. Works for any pytree of
arrays (params, AdamWState, caches) and keeps the last ``keep`` steps.
"""
from __future__ import annotations

import hashlib
import json
import re
import shutil
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.training.optimizer import AdamWState

_MANIFEST = "manifest.json"


def _fingerprint(cfg: ModelConfig) -> str:
    key = (f"{cfg.name}|{cfg.n_layers}|{cfg.d_model}|{cfg.n_heads}|"
           f"{cfg.n_kv_heads}|{cfg.d_ff}|{cfg.vocab}")
    return hashlib.sha256(key.encode()).hexdigest()[:16]


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir, cfg: ModelConfig, params, opt_state=None,
                    step: int = 0, keep: int = 3) -> Path:
    """Write checkpoint step; returns its directory."""
    root = Path(ckpt_dir)
    out = root / f"step_{step:08d}"
    out.mkdir(parents=True, exist_ok=True)

    def dump(name, tree):
        leaves, _ = _flatten(tree)
        np.savez(out / f"{name}.npz",
                 **{f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)})

    dump("params", params)
    manifest = {
        "step": step,
        "fingerprint": _fingerprint(cfg),
        "arch": cfg.name,
        "has_opt": opt_state is not None,
    }
    if opt_state is not None:
        dump("opt_mu", opt_state.mu)
        dump("opt_nu", opt_state.nu)
        manifest["opt_step"] = int(opt_state.step)
    (out / _MANIFEST).write_text(json.dumps(manifest))

    # retention
    steps = sorted(p for p in root.glob("step_*") if p.is_dir())
    for old in steps[:-keep]:
        shutil.rmtree(old)
    return out


def latest_step(ckpt_dir) -> Optional[int]:
    root = Path(ckpt_dir)
    best = None
    for p in root.glob("step_*"):
        m = re.match(r"step_(\d+)", p.name)
        if m:
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def restore_checkpoint(ckpt_dir, cfg: ModelConfig, params_like,
                       opt_state_like=None, step: Optional[int] = None):
    """Restore into the structure of ``params_like`` (shape/dtype checked).

    Returns (params, opt_state_or_None, step).
    """
    root = Path(ckpt_dir)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    src = root / f"step_{step:08d}"
    manifest = json.loads((src / _MANIFEST).read_text())
    if manifest["fingerprint"] != _fingerprint(cfg):
        raise ValueError(
            f"checkpoint is for arch {manifest['arch']!r}, not {cfg.name!r}")

    def load(name, like):
        leaves, treedef = _flatten(like)
        with np.load(src / f"{name}.npz") as z:
            new = []
            for i, ref in enumerate(leaves):
                arr = z[f"leaf_{i}"]
                if tuple(arr.shape) != tuple(ref.shape):
                    raise ValueError(
                        f"{name} leaf {i}: shape {arr.shape} != {ref.shape}")
                new.append(jax.numpy.asarray(arr, dtype=ref.dtype))
        return jax.tree.unflatten(treedef, new)

    params = load("params", params_like)
    opt_state = None
    if opt_state_like is not None and manifest.get("has_opt"):
        opt_state = AdamWState(
            jax.numpy.asarray(manifest["opt_step"], jax.numpy.int32),
            load("opt_mu", opt_state_like.mu),
            load("opt_nu", opt_state_like.nu))
    return params, opt_state, step

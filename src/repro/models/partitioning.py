"""Logical-axis partitioning (MaxText-style, lightweight).

Model code names tensor dims with *logical* axes ('batch', 'embed', 'q',
'ff', 'expert', ...). A ``ShardingRules`` maps logical names to mesh axes.
Outside a rules context everything is a no-op, so the same model code runs
on a single CPU device and under the 512-chip dry-run meshes.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axis (or tuple of mesh axes)."""

    rules: Dict[str, MeshAxes] = field(default_factory=dict)
    axis_sizes: Dict[str, int] = field(default_factory=dict)
    # concrete jax Mesh — required only for the explicit shard_map
    # expert-parallel path (models/moe.py); None elsewhere
    mesh: object = None

    def spec(self, axes: Tuple[Optional[str], ...]) -> P:
        return P(*(self.rules.get(a) if a is not None else None for a in axes))

    def size(self, logical: str) -> int:
        """Number of shards the mapping of `logical` implies (1 if unknown)."""
        m = self.rules.get(logical)
        if m is None:
            return 1
        axes = m if isinstance(m, tuple) else (m,)
        n = 1
        for a in axes:
            n *= self.axis_sizes.get(a, 1)
        return n


# Baseline (paper-faithful megatron-style TP + DP) rule sets -----------------

def tp_rules(*, multi_pod: bool = False, expert_parallel: bool = False,
             decode_kv: str = "heads", fsdp: bool = False,
             axis_sizes: Optional[Dict[str, int]] = None,
             mesh=None) -> ShardingRules:
    """Sharding rules over the production mesh.

    Baseline (paper-faithful analogue): megatron-style TP over 'model',
    data parallel over 'data' (x 'pod').

    expert_parallel: shard the expert axis over 'model' (all-to-all MoE)
      instead of sharding every expert's d_ff (megatron MoE-TP).
    decode_kv: 'heads' shards the decode KV cache over kv-heads (classic
      TP), 'seq' shards it over sequence (flash-decode style) — a
      beyond-paper optimization knob, see EXPERIMENTS.md §Perf.
    fsdp: beyond-paper training mode — batch over BOTH mesh axes (pure
      data parallel), weights/optimizer ZeRO-3 sharded over
      ('data' x 'model') via their two named dims; XLA materializes the
      per-layer all-gathers. Kills the TP activation all-reduces that
      dominate the baseline's collective roofline term.

    Weight dims and activation dims use distinct logical names
    ('embed' vs 'act_embed', ...) so FSDP can shard parameters along
    dims whose activation counterparts stay replicated.
    """
    batch = ("pod", "data") if multi_pod else ("data",)
    if fsdp:
        # FSDP(+EP) training modes — beyond-paper §Perf variants.
        # Dense FSDP: batch over BOTH axes (pure DP, global_batch=256 ==
        #   data x model), weights ZeRO-3 sharded 256-way via two dims.
        # MoE hybrid (fsdp+expert_parallel): batch over 'data' only so the
        #   'model' axis can carry the EXPERT dim — tokens all-to-all to
        #   their expert's shard instead of every device gathering every
        #   expert. Weights still ZeRO-3 over 'data'.
        # Under multi-pod the pod axis replicates (context parallelism
        # would be the next step — noted in EXPERIMENTS.md §Perf).
        batch = ("data",) if expert_parallel else ("data", "model")
        rules: Dict[str, MeshAxes] = {
            "batch": batch,
            "seq": None,
            # weights: ZeRO-3 sharded over both axes via two dims
            "embed": "data",
            "vocab": "model",
            "q": "model",
            "kv": "model",
            "heads": None,
            # expert-parallel: the expert dim takes 'model'; the per-expert
            # d_ff stays whole (gathered per use like other ZeRO weights)
            "ff": None if expert_parallel else "model",
            "expert": "model" if expert_parallel else None,
            "inner": "model",
            "state": None,
            "layers": None,
            # activations: replicated along feature dims (pure DP), except
            # the expert dim in the MoE hybrid (drives the all-to-all)
            "act_embed": None,
            "act_ff": None,
            "act_inner": None,
            "act_vocab": None,
            "act_heads": None,
            "act_kv_heads": None,
            "act_expert": "model" if expert_parallel else None,
            "kv_seq": None,
            "kv_heads": None,
        }
        return ShardingRules(rules, axis_sizes or {}, mesh)
    rules = {
        "batch": batch,
        "seq": None,
        "embed": None,
        "vocab": "model",
        "q": "model",            # q_dim = n_heads * head_dim
        "kv": "model",           # kv_dim = n_kv_heads * head_dim
        "heads": "model",
        "ff": None if expert_parallel else "model",
        "expert": "model" if expert_parallel else None,
        "inner": "model",        # ssm inner dim
        "state": None,
        "layers": None,
        "act_embed": None,
        "act_ff": None if expert_parallel else "model",
        "act_inner": "model",
        "act_vocab": "model",
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_expert": "model" if expert_parallel else None,
        "kv_seq": "model" if decode_kv == "seq" else None,
        "kv_heads": "model" if decode_kv == "heads" else None,
    }
    return ShardingRules(rules, axis_sizes or {}, mesh)


_tls = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = current_rules()
    _tls.rules = rules
    try:
        yield
    finally:
        _tls.rules = prev


def shard(x, *axes: Optional[str]):
    """Constrain ``x`` to the sharding implied by logical ``axes``.

    No-op when no rules are active (single-device tests). Dims whose size
    is not divisible by the mapped mesh-axis product are left unsharded —
    forcing e.g. 8 whisper heads onto a 16-way model axis makes XLA
    replicate the whole tensor ('involuntary full rematerialization'),
    which showed up as ~1.2 TB/step of spurious all-gathers.
    """
    rules = current_rules()
    if rules is None:
        return x
    entries = []
    for dim, a in zip(x.shape, axes):
        m = rules.rules.get(a) if a is not None else None
        if m is None:
            entries.append(None)
            continue
        n = rules.size(a)
        entries.append(m if n <= 1 or dim % n == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*entries))


def logical_to_pspec(axes: Tuple[Optional[str], ...],
                     rules: Optional[ShardingRules]) -> P:
    if rules is None:
        return P()
    return rules.spec(axes)

"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887] — 8-layer period: attention at position 4, Mamba
elsewhere; MoE replaces the MLP on every other layer. We implement the
SSM layers with Mamba2/SSD (TPU-friendly matmul form); the original uses
Mamba1 — noted in DESIGN.md as a deliberate TPU adaptation.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig, SSMConfig

_PATTERN = tuple(
    LayerSpec(mixer=("attn" if i == 4 else "ssm"),
              ffn=("moe" if i % 2 == 1 else "mlp"))
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    pattern=_PATTERN,
    moe=MoEConfig(n_experts=16, top_k=2),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=256),
    source="arXiv:2403.19887",
)

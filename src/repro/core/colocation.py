"""Operator-level co-location interference model (paper §3.5, Fig. 6).

The paper profiles pairs of operators executing concurrently on one NPU
and finds that operators with *different* resource footprints (AI Core vs
AI Vector vs DMA) interfere little, while similar footprints interfere
strongly. TPU analogue: MXU (systolic matmul) vs VPU (vector) vs HBM DMA
vs ICI collectives. We keep the insight as a calibrated pairwise matrix
and derive stage-level slowdowns from each stage's operator mix.

Stage profiles:
* Encode  (ViT forward)      — MXU-dominated, compute-bound.
* Prefill (long-seq forward) — MXU-dominated with HBM traffic.
* Decode  (batched 1-token)  — HBM-dominated, memory-bound.

This yields the paper's ordering: co-locating Encode with Decode is cheap
(complementary), Encode with Prefill is moderately expensive (both MXU),
and duplicate stages are the worst.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Mapping, Tuple

OPERATORS = ("matmul", "vector", "dma", "collective")

# pairwise latency-increase factors when two operator classes co-execute
# (symmetric; 1.0 = no interference). Calibrated to the *structure* of the
# paper's Fig. 6 heatmap: like-with-like is expensive.
_M: Dict[Tuple[str, str], float] = {
    ("matmul", "matmul"): 1.90,
    ("vector", "vector"): 1.80,
    ("dma", "dma"): 1.85,
    ("collective", "collective"): 1.60,
    ("matmul", "vector"): 1.25,
    ("matmul", "dma"): 1.10,
    ("matmul", "collective"): 1.05,
    ("vector", "dma"): 1.20,
    ("vector", "collective"): 1.10,
    ("dma", "collective"): 1.30,
}


def op_interference(a: str, b: str) -> float:
    return _M.get((a, b)) or _M.get((b, a)) or 1.0


# stage operator mixes (fractions of busy time per operator class)
STAGE_MIX: Dict[str, Dict[str, float]] = {
    "E": {"matmul": 0.80, "vector": 0.15, "dma": 0.05, "collective": 0.00},
    "P": {"matmul": 0.70, "vector": 0.10, "dma": 0.15, "collective": 0.05},
    "D": {"matmul": 0.20, "vector": 0.10, "dma": 0.65, "collective": 0.05},
}


def stage_slowdown(stage: str, concurrent: Iterable[str]) -> float:
    """Latency multiplier for `stage` while `concurrent` stages share the
    chip. Multiplicative across concurrent stages (>=1.0)."""
    mix_a = STAGE_MIX[stage]
    factor = 1.0
    for other in concurrent:
        mix_b = STAGE_MIX[other]
        pair = sum(mix_a[a] * mix_b[b] * op_interference(a, b)
                   for a in OPERATORS for b in OPERATORS)
        factor *= max(pair, 1.0)
    return factor


def interference_heatmap() -> Dict[Tuple[str, str], float]:
    """Full stage x stage matrix (for the Fig. 6 benchmark)."""
    return {(a, b): stage_slowdown(a, [b])
            for a in STAGE_MIX for b in STAGE_MIX}

"""Jitted per-instance step functions: encode / prefill / decode / insert.

These are the *real-compute* building blocks used by the serving engine
(CPU-scale configs) and by the dry-run (full-scale configs lowered on the
production meshes).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import decode_forward, prefill_forward
from repro.serving.sampling import sample


def make_prefill_fn(cfg: ModelConfig):
    @jax.jit
    def prefill_fn(params, tokens, lengths, caches, mm_embeds=None,
                   enc_frames=None):
        logits, new_caches = prefill_forward(
            params, cfg, tokens, caches, lengths=lengths,
            mm_embeds=mm_embeds, enc_frames=enc_frames)
        return logits, new_caches

    return prefill_fn


def make_decode_fn(cfg: ModelConfig, temperature: float = 0.0):
    @functools.partial(jax.jit, donate_argnums=(2,))
    def decode_fn(params, tokens, caches, key):
        logits, new_caches = decode_forward(params, cfg, tokens, caches)
        next_tok = sample(logits, key, temperature)
        return next_tok, new_caches

    return decode_fn


def make_insert_fn(cfg: ModelConfig):
    """Copy one request's prefilled cache (batch=1) into batch slot `slot`
    of the decode cache — the P->D handoff on the Decode instance."""

    @functools.partial(jax.jit, donate_argnums=(1,), static_argnums=(2,))
    def insert_fn(src_caches, dst_caches, slot: int):
        def ins(dst, src):
            if dst.ndim == 1:                       # lengths (B,)
                return dst.at[slot].set(src[0])
            # stacked caches: (R, B, ...) — batch axis 1
            if src.ndim >= 3 and src.shape[2] != dst.shape[2]:
                cfgpad = [(0, 0)] * src.ndim
                cfgpad[2] = (0, dst.shape[2] - src.shape[2])
                fill = -1 if src.dtype == jnp.int32 else 0
                src = jnp.pad(src, cfgpad, constant_values=fill)
            return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))

        return jax.tree.map(ins, dst_caches, src_caches)

    return insert_fn

"""Training launcher.

CPU-scale real run:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --steps 50

Production-mesh dry-run of the same step is in repro.launch.dryrun.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.models.model import init_params
from repro.models.params import count_params
from repro.training.data import synthetic_batches
from repro.training.optimizer import AdamW
from repro.training.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (default: reduced for CPU)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={count_params(params):,}")

    opt = AdamW(lr=args.lr, warmup_steps=max(args.steps // 10, 1))
    step_fn = jax.jit(make_train_step(cfg, opt, remat=False))
    opt_state = opt.init(params)

    t0 = time.time()
    for i, batch in enumerate(synthetic_batches(
            cfg, args.batch, args.seq, args.steps,
            mm=cfg.frontend is not None and cfg.encoder is None)):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                  f"aux={float(metrics['aux']):.4f} "
                  f"({(time.time()-t0)/(i+1)*1e3:.0f} ms/step)")


if __name__ == "__main__":
    main()

"""Config system for EPD-Serve reproduction.

A ``ModelConfig`` fully describes one backbone: layer pattern (attention /
sliding-window attention / SSM mixers, dense / MoE ffns), GQA geometry,
vocab, and the (stubbed) modality frontend for VLM / audio archs.

Every assigned architecture gets one module in this package defining
``CONFIG``; ``repro.configs.get_config(name)`` resolves it.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    # capacity factor for einsum (dropped-token) dispatch; tokens per expert
    # = ceil(tokens * top_k / n_experts * capacity_factor)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD geometry."""

    state_dim: int = 128          # N: per-head state size
    head_dim: int = 64            # P: channels per SSD head
    expand: int = 2               # inner dim = expand * d_model
    chunk_size: int = 256         # SSD chunk length
    conv_width: int = 4           # depthwise causal conv width

    def inner_dim(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.inner_dim(d_model) // self.head_dim


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: emits precomputed embeddings (see DESIGN.md).

    ``kind`` is 'vision' or 'audio'. ``tokens_per_item`` is the number of
    embedding tokens one image / audio clip contributes; ``feature_dim`` is
    the frontend's native output dim (projected to d_model by a learned
    projector, which IS implemented — only the encoder trunk is stubbed).
    """

    kind: str
    tokens_per_item: int
    feature_dim: int


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder half of an encoder-decoder backbone (whisper-style)."""

    n_layers: int
    n_ctx: int                    # encoder sequence length (audio frames)


@dataclass(frozen=True)
class LayerSpec:
    """One layer position in the repeating pattern.

    mixer: 'attn' (full causal), 'swa' (sliding window), 'ssm' (Mamba2 SSD)
    ffn:   'mlp' (gated dense), 'moe' (top-k experts), 'none'
    """

    mixer: str = "attn"
    ffn: str = "mlp"


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: Optional[FrontendConfig] = None
    encoder: Optional[EncoderConfig] = None
    sliding_window: Optional[int] = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""              # citation for the config

    # -- derived ----------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern length {len(self.pattern)}"
        )

    @property
    def n_repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def has_attention(self) -> bool:
        return any(s.mixer in ("attn", "swa") for s in self.pattern)

    @property
    def attn_layers(self) -> Tuple[int, ...]:
        """Absolute indices of attention layers (for KV-cache layout)."""
        out = []
        for r in range(self.n_repeats):
            for i, s in enumerate(self.pattern):
                if s.mixer in ("attn", "swa"):
                    out.append(r * len(self.pattern) + i)
        return tuple(out)

    @property
    def ssm_layers(self) -> Tuple[int, ...]:
        out = []
        for r in range(self.n_repeats):
            for i, s in enumerate(self.pattern):
                if s.mixer == "ssm":
                    out.append(r * len(self.pattern) + i)
        return tuple(out)

    @property
    def sub_quadratic(self) -> bool:
        """True if decode memory is o(seq): SSM-only, or SWA-capped KV."""
        mixers = {s.mixer for s in self.pattern}
        if mixers <= {"ssm"}:
            return True
        if "attn" in mixers:
            # hybrid with a few full-attention layers still scales linearly
            # in KV but with a small constant; the brief treats SSM-dominant
            # hybrids as long-context capable.
            return self.arch_type == "hybrid"
        if mixers <= {"swa", "ssm"}:
            return self.sliding_window is not None
        return False

    def reduced(self, *, n_layers: int = 0, d_model: int = 0,
                n_experts: int = 0, vocab: int = 0) -> "ModelConfig":
        """A small same-family variant for CPU smoke tests."""
        pat = len(self.pattern)
        nl = n_layers or min(self.n_layers, 2 * pat if pat <= 2 else pat)
        dm = d_model or min(self.d_model, 256)
        nh = max(1, dm // 64)
        # keep the GQA grouping qualitatively (grouped vs MHA)
        ratio = max(1, self.n_heads // max(self.n_kv_heads, 1))
        nkv = max(1, nh // ratio)
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe, n_experts=n_experts or min(moe.n_experts, 4),
                top_k=min(moe.top_k, n_experts or min(moe.n_experts, 4)))
        ssm = self.ssm
        if ssm is not None:
            ssm = dataclasses.replace(ssm, state_dim=min(ssm.state_dim, 32),
                                      head_dim=32, chunk_size=32)
        fe = self.frontend
        if fe is not None:
            fe = dataclasses.replace(fe, tokens_per_item=min(fe.tokens_per_item, 16),
                                     feature_dim=min(fe.feature_dim, 128))
        enc = self.encoder
        if enc is not None:
            enc = dataclasses.replace(enc, n_layers=2, n_ctx=32)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=nl, d_model=dm, n_heads=nh, n_kv_heads=nkv,
            head_dim=dm // nh if nh else 64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=vocab or min(self.vocab, 512),
            moe=moe, ssm=ssm, frontend=fe, encoder=enc,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
        )

    # -- size accounting ----------------------------------------------------
    def param_count(self) -> int:
        """Total parameter count (embeddings included)."""
        total = self.vocab * self.d_model          # embed
        if not self.tie_embeddings:
            total += self.vocab * self.d_model     # lm head
        total += self.d_model                      # final norm
        for spec in self.pattern:
            total += self.n_repeats * self._layer_params(spec)
        if self.encoder is not None:
            enc_layer = (
                2 * self.d_model  # norms
                + 4 * self.d_model * self.d_model  # self-attn qkvo (MHA)
                + 2 * self.d_model * self.d_ff     # non-gated mlp
            )
            total += self.encoder.n_layers * enc_layer + self.d_model
        if self.frontend is not None:
            total += self.frontend.feature_dim * self.d_model  # projector
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        total = self.vocab * self.d_model
        if not self.tie_embeddings:
            total += self.vocab * self.d_model
        total += self.d_model
        for spec in self.pattern:
            total += self.n_repeats * self._layer_params(spec, active=True)
        return total

    def _layer_params(self, spec: LayerSpec, active: bool = False) -> int:
        n = 0
        d = self.d_model
        if spec.mixer in ("attn", "swa"):
            n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            n += d  # norm
            if self.encoder is not None:
                # decoder layers of an enc-dec backbone carry cross-attention
                n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d + d
        elif spec.mixer == "ssm":
            ssm = self.ssm
            inner = ssm.inner_dim(d)
            nh = ssm.n_heads(d)
            # in_proj -> [z, x, B, C, dt], out_proj, conv, norm
            zxbcdt = 2 * inner + 2 * ssm.state_dim + nh
            n += d * zxbcdt + inner * d
            n += ssm.conv_width * (inner + 2 * ssm.state_dim)
            n += 2 * nh + d  # A_log, D, norm
        if spec.ffn == "mlp":
            n += 3 * d * self.d_ff + d
        elif spec.ffn == "moe":
            e = self.moe.top_k if active else self.moe.n_experts
            n += e * 3 * d * self.d_ff + d + d * self.moe.n_experts  # router
        return n


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

"""Iteration-level batch composition — continuous batching for all stages.

Production inference engines (vLLM, sglang's hybrid coordinator) never
serve one request at a time: every device iteration a scheduler composes
a batch from the READY prefill chunks of *different* requests plus every
ongoing decode, admits finished prefills against free decode capacity,
and keeps the device saturated between one request's chunks instead of
blocking on its serial chunk loop. This module is that composer for the
EPD cluster: the :class:`IterationScheduler` produces one
:class:`BatchPlan` per step, and an executor (``Engine.step`` for a
fused engine, ``EPDCluster.run_continuous`` for the disaggregated
cluster) carries it out against real engines.

Scheduling state lives in :class:`PrefillJob` wrappers so the scheduler
stays decoupled from the execution layer: the executor attaches the
engine-side ``PrefillTask`` (the resumable chunk state machine extracted
from ``Engine._prefill_chunked``) on first touch, and dependency edges —
the E->P feature-arrival barrier of the async overlap arm, the
whole-request barrier of the sync arm — are plain ``ready_at`` clocks
the plan respects: a job whose next chunk would cross an unmet barrier
is reported as *stalled* and other jobs' chunks fill the iteration.

The :class:`StreamTimeline` is the modeled clock for disaggregated
throughput accounting: the Prefill device and the Decode device are
separate streams, so a serial driver's makespan is the SUM of both
streams' work while the continuous scheduler's is their MAX (plus
unhidden barriers). ``fused=True`` collapses it to one clock — exactly
the serial chunk-loop baseline the benchmark compares against.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

from repro.serving.request import Request


@dataclass
class PrefillJob:
    """One request's prefill as the scheduler sees it.

    ``task`` (the engine-side chunk state machine) and ``result`` (the
    ``(first_token, payload)`` pair once the prefill finished) are
    attached by the executor; the scheduler only reads them.

    Barrier clocks (modeled time, same timebase as ``plan(now=...)``):
    ``ready_at``          — nothing of this job may run earlier (the
                            sync-arm E->P push, or request arrival);
    ``feature_ready_at``  — the async-arm feature arrival: chunks whose
                            window stays before the image run ignore it,
                            the chunk overlapping the run waits for it;
    ``retry_at``          — a READY job parked after a failed decode
                            admission (e.g. a transfer fault): the
                            capped retry backoff as a dependency edge —
                            admission skips the job until the clock
                            reaches it, other ready jobs may overtake.
    """

    req: Request
    n_tokens: int = 0                  # prompt + mm tokens (prefill width)
    chunk: int = 0                     # the engine's chunk window (tokens)
    ready_at: float = 0.0
    feature_ready_at: float = 0.0
    retry_at: float = 0.0
    task: Any = None
    result: Optional[Tuple[int, Any]] = None
    meta: Dict[str, Any] = field(default_factory=dict)

    def blocked_reason(self, now: float) -> Optional[str]:
        """Why this job cannot advance a chunk at modeled time ``now``
        (None = schedulable). Before the task exists the feature barrier
        is judged from the request shape alone: the first chunk window
        is [0, chunk), so it needs features iff the image run starts
        inside it — conservative only when a prefix hit would have
        skipped past the run, which the task-attached check repairs on
        the next plan."""
        if self.ready_at > now:
            return "sync_barrier"
        if self.feature_ready_at > now:
            if self.task is not None:
                if self.task.needs_features_next():
                    return "feature_barrier"
            elif (self.req.is_multimodal and self.req.mm_tokens
                  and self.req.mm_pos < min(self.chunk or self.n_tokens,
                                            self.n_tokens)):
                return "feature_barrier"
        return None

    def barrier_time(self) -> float:
        """Earliest modeled time the next chunk could run (for idle
        jumps when every job is barrier-stalled)."""
        t = self.ready_at
        if self.feature_ready_at and (
                self.task.needs_features_next() if self.task is not None
                else True):
            t = max(t, self.feature_ready_at)
        return t


@dataclass
class BatchPlan:
    """What one device iteration executes.

    ``chunks``  — jobs to advance by ONE prefill chunk each, in order
                  (round-robin across requests, so a long prompt never
                  monopolizes the prefill stream);
    ``admit``   — finished prefills to insert into free decode slots
                  (FIFO over the ready queue, capped at ``free_slots``);
    ``decode``  — run one lock-step decode iteration over active slots;
    ``stalled`` — (job, reason) pairs that could not be scheduled this
                  step: unmet barriers, the live-prefill cap, or a pool
                  stall carried over from execution.
    """

    step: int
    chunks: List[PrefillJob] = field(default_factory=list)
    admit: List[PrefillJob] = field(default_factory=list)
    decode: bool = False
    stalled: List[Tuple[PrefillJob, str]] = field(default_factory=list)

    @property
    def prefill_tokens(self) -> int:
        return sum(j.task.next_chunk_tokens if j.task is not None
                   else min(j.chunk or j.n_tokens, j.n_tokens)
                   for j in self.chunks)

    @property
    def empty(self) -> bool:
        return not (self.chunks or self.admit or self.decode)


class IterationScheduler:
    """Composes one :class:`BatchPlan` per device iteration.

    Queues: ``waiting`` (submitted, prefill not started — holds no pool
    pages yet), ``live`` (prefill in flight, bounded by
    ``max_live_prefills`` so concurrent chunk state cannot eat the page
    pool), ``ready`` (prefill finished, awaiting decode admission — the
    payload holds its pages until the insert lands).

    Admission policy: ready prefills admit FIFO against the executor-
    reported free decode slots; an insert denied by the decode pool
    (``requeue_ready``) returns to the queue head and retries next
    iteration — decode drain / preemption frees pages between steps.
    ``chunk_budget_tokens`` caps the prefill tokens composed into one
    iteration (None = one chunk from every schedulable live job, the
    max-interleave default).
    """

    def __init__(self, *, max_live_prefills: int = 4,
                 chunk_budget_tokens: Optional[int] = None,
                 adaptive_chunking: bool = False,
                 min_chunk_budget: int = 16,
                 max_chunk_budget: int = 1 << 20):
        if max_live_prefills < 1:
            raise ValueError("need max_live_prefills >= 1")
        self.max_live_prefills = max_live_prefills
        self.chunk_budget_tokens = chunk_budget_tokens
        # adaptive chunk sizing (behind a flag): the per-iteration
        # prefill-token budget shrinks when decode slots starve (ready
        # prefills queue against zero free slots — decode drain is the
        # bottleneck, so composing more prefill only grows the held-page
        # working set) and grows back while the decode pool has headroom
        # and no admission backlog exists. Scheduling-only: greedy
        # outputs are bit-identical at any budget.
        self.adaptive_chunking = adaptive_chunking
        self.min_chunk_budget = min_chunk_budget
        self.max_chunk_budget = max_chunk_budget
        self._budget: Optional[int] = chunk_budget_tokens
        self.budget_shrinks = 0
        self.budget_grows = 0
        self.waiting: Deque[PrefillJob] = deque()
        self.live: List[PrefillJob] = []
        self.ready: Deque[PrefillJob] = deque()
        self._rr = 0
        self.steps = 0
        self.stall_counts: Dict[str, int] = {}

    # ---- intake / state transitions (executor-driven) ----
    def submit(self, job: PrefillJob) -> PrefillJob:
        self.waiting.append(job)
        return job

    def mark_ready(self, job: PrefillJob) -> None:
        """Executor: ``job``'s last chunk ran and ``job.result`` is set."""
        if job.result is None:
            raise ValueError("mark_ready before the job has a result")
        self.live.remove(job)
        self.ready.append(job)

    def requeue_ready(self, job: PrefillJob) -> None:
        """Executor: decode admission was denied — retry next iteration
        from the queue head (FIFO fairness, no overtaking)."""
        self.ready.appendleft(job)
        self.note_stall(job, "admission")

    def park_ready(self, job: PrefillJob, retry_at: float,
                   reason: str = "retry_wait") -> None:
        """Executor: admission FAILED in a retryable way (a transfer
        fault drew on the P->D hand-off). The job returns to the queue
        head with a ``retry_at`` barrier: the plan composes around it —
        younger ready jobs may admit first — and ``next_barrier_time``
        exposes the clock so an otherwise-idle loop jumps straight to
        the retry instead of spinning."""
        job.retry_at = retry_at
        self.ready.appendleft(job)
        self.note_stall(job, reason)

    def note_stall(self, job: PrefillJob, reason: str) -> None:
        self.stall_counts[reason] = self.stall_counts.get(reason, 0) + 1

    # ---- introspection ----
    @property
    def has_prefill_work(self) -> bool:
        return bool(self.waiting or self.live)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.live or self.ready)

    def next_barrier_time(self, after: Optional[float] = None,
                          ) -> Optional[float]:
        """Earliest barrier among jobs that could actually run — the
        idle-jump target when a plan came back empty because every job
        is stalled on a future arrival. Waiting jobs count only while
        the live window has headroom: with the window full their
        barriers are unreachable until a live job finishes, so jumping
        to one would stall the clock in the past. Parked READY jobs
        (admission retry backoff) count too: their ``retry_at`` is the
        earliest the re-admission may run.

        ``after`` drops barriers at or before that clock: a pool-stalled
        live job's ELAPSED barrier must not mask a parked job's future
        ``retry_at`` — jumping to the retry releases the parked payload
        and un-deadlocks the pool, where restarting in place never
        advances the clock."""
        jobs = list(self.live)
        if len(self.live) < self.max_live_prefills:
            jobs += list(self.waiting)
        ts = [j.barrier_time() for j in jobs]
        ts += [j.retry_at for j in self.ready if j.retry_at > 0.0]
        if after is not None:
            ts = [t for t in ts if t > after]
        return min(ts) if ts else None

    def _effective_budget(self, free_slots: int) -> Optional[int]:
        """The prefill-token budget this iteration. Static unless
        ``adaptive_chunking``: then decode starvation (finished prefills
        queued against zero free slots) halves it down to
        ``min_chunk_budget`` and admission headroom (free slots, no
        ready backlog) doubles it back up to ``max_chunk_budget``."""
        if not self.adaptive_chunking:
            return self.chunk_budget_tokens
        if free_slots == 0 and self.ready:
            cur = self._budget
            if cur is None:
                # unlimited so far: seed from the widest live chunk so
                # the first shrink is meaningful
                cur = max((j.task.next_chunk_tokens if j.task is not None
                           else min(j.chunk or j.n_tokens, j.n_tokens))
                          for j in self.live) * len(self.live)
            nxt = max(self.min_chunk_budget, cur // 2)
            if nxt != cur:
                self.budget_shrinks += 1
            self._budget = nxt
        elif free_slots > 0 and not self.ready \
                and self._budget is not None:
            nxt = min(self.max_chunk_budget, self._budget * 2)
            if nxt != self._budget:
                self.budget_grows += 1
            self._budget = nxt
        return self._budget

    # ---- the per-iteration composer ----
    def plan(self, *, now: float = 0.0, free_slots: int = 0,
             active_decode: int = 0) -> BatchPlan:
        """Compose one iteration: admissions first (a freed slot is
        ground truth the executor just reported), then promote waiting
        jobs into the live window, then one chunk from each schedulable
        live job starting at the round-robin cursor. ``decode`` is set
        whenever ongoing decodes exist or an admission will create one
        this step."""
        self.steps += 1
        plan = BatchPlan(step=self.steps)
        n = max(0, free_slots)
        if n and self.ready:
            # admission skips jobs parked on a future retry_at (the
            # transfer-fault backoff edge): the plan composes around
            # them — later ready jobs may overtake — and they rejoin
            # FIFO order once the clock reaches the barrier.
            keep: List[PrefillJob] = []
            while self.ready and len(plan.admit) < n:
                job = self.ready.popleft()
                if job.retry_at > now:
                    keep.append(job)
                    plan.stalled.append((job, "retry_wait"))
                    self.note_stall(job, "retry_wait")
                    continue
                plan.admit.append(job)
            for job in reversed(keep):
                self.ready.appendleft(job)
        while self.waiting and len(self.live) < self.max_live_prefills:
            self.live.append(self.waiting.popleft())
        if self.live:
            budget = self._effective_budget(free_slots)
            order = [self.live[(self._rr + i) % len(self.live)]
                     for i in range(len(self.live))]
            self._rr = (self._rr + 1) % max(len(self.live), 1)
            for job in order:
                why = job.blocked_reason(now)
                if why is not None:
                    plan.stalled.append((job, why))
                    self.note_stall(job, why)
                    continue
                ntok = (job.task.next_chunk_tokens if job.task is not None
                        else min(job.chunk or job.n_tokens, job.n_tokens))
                if budget is not None and plan.chunks and ntok > budget:
                    plan.stalled.append((job, "budget"))
                    continue
                plan.chunks.append(job)
                if budget is not None:
                    budget -= ntok
        plan.decode = bool(active_decode or plan.admit)
        return plan


@dataclass
class StreamTimeline:
    """Modeled two-stream clock for disaggregated continuous batching.

    The Prefill device and the Decode device(s) are separate hardware:
    each charge advances its own stream, ``not_before`` expresses a
    dependency edge (a request's first decode cannot start before its
    prefill + exposed transfer; a barrier chunk cannot start before its
    feature arrives), and the makespan is the latest stream. A serial
    driver runs the same operations on one python thread with each
    stage blocking the next, so ``fused=True`` serializes every charge
    onto a single clock — the baseline the throughput benchmark divides
    by."""

    fused: bool = False
    t_encode: float = 0.0
    t_prefill: float = 0.0
    t_decode: float = 0.0

    def _charge(self, attr: str, dur: float, not_before: float) -> float:
        if self.fused:
            t = max(self.t_encode, self.t_prefill, self.t_decode,
                    not_before) + dur
            self.t_encode = self.t_prefill = self.t_decode = t
            return t
        t = max(getattr(self, attr), not_before) + dur
        setattr(self, attr, t)
        return t

    def charge_encode(self, dur: float, not_before: float = 0.0) -> float:
        return self._charge("t_encode", dur, not_before)

    def charge_prefill(self, dur: float, not_before: float = 0.0) -> float:
        return self._charge("t_prefill", dur, not_before)

    def charge_decode(self, dur: float, not_before: float = 0.0) -> float:
        return self._charge("t_decode", dur, not_before)

    @property
    def makespan(self) -> float:
        return max(self.t_encode, self.t_prefill, self.t_decode)

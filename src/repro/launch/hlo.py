"""HLO text analysis: collective-communication byte accounting.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled (post-SPMD-partitioning) HLO and sum collective RESULT bytes per
op kind — the collective term of the roofline reads from this.

Two subtleties:
* operands are printed without inline types in modern XLA, so we account
  the result shape (for all-gather that's the full gathered tile each
  device materializes; for all-reduce the reduced tile — a reasonable
  per-device traffic proxy; ring all-reduce moves ~2x, noted in
  EXPERIMENTS.md).
* collectives inside ``while`` bodies (our layer scans / microbatch
  accumulation) appear ONCE in the text but execute trip-count times —
  we recover trip counts from the loop-condition constant and multiply.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_OP_RE = re.compile(
    r"=\s*(\([^=]*?\)|\S+)\s+(" + "|".join(COLLECTIVES) + r")(-start)?\(")
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_WHILE_RE = re.compile(r"\bwhile\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_WHILE_RE2 = re.compile(r"\bwhile\(.*?body=%?([\w.\-]+),\s*condition=%?([\w.\-]+)")
_CALL_RE = re.compile(r"\b(?:calls|to_apply|branch_computations)=\{?%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """computation name -> its body text."""
    comps: Dict[str, str] = {}
    cur_name = None
    cur_lines = []
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(ENTRY\s+)?%?([\w.\-]+)\s*(\([^)]*\))?.*\{\s*$", line)
        if m and ("(" in line and "{" in line):
            cur_name = m.group(2)
            cur_lines = []
            continue
        if line.strip() == "}" and cur_name is not None:
            comps[cur_name] = "\n".join(cur_lines)
            cur_name = None
            continue
        if cur_name is not None:
            cur_lines.append(line)
    return comps


def _direct_bytes(body: str) -> Dict[str, int]:
    out: Dict[str, int] = defaultdict(int)
    for line in body.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        if "-done(" in line:
            continue
        kind = m.group(2)
        result = m.group(1)
        nbytes = sum(_shape_bytes(d, dims)
                     for d, dims in _SHAPE_RE.findall(result))
        out[kind] += nbytes
    return out


def _trip_count(cond_body: str) -> int:
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    return max(consts) if consts else 1


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Dynamic (trip-count weighted) collective result-bytes per kind."""
    comps = _split_computations(hlo_text)
    if not comps:                       # fallback: flat scan
        return dict(_direct_bytes(hlo_text))

    memo: Dict[str, Dict[str, int]] = {}

    def total(name: str, stack=()) -> Dict[str, int]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return {}
        body = comps[name]
        acc = defaultdict(int, _direct_bytes(body))
        for line in body.splitlines():
            mw = _WHILE_RE.search(line) or _WHILE_RE2.search(line)
            if mw:
                a, b = mw.group(1), mw.group(2)
                # figure out which is the condition (contains a compare)
                cond, wbody = (a, b) if "compare" in comps.get(a, "") else (b, a)
                trips = _trip_count(comps.get(cond, ""))
                sub = total(wbody, stack + (name,))
                for k, v in sub.items():
                    acc[k] += v * trips
                continue
            for cal in _CALL_RE.findall(line):
                sub = total(cal, stack + (name,))
                for k, v in sub.items():
                    acc[k] += v
        memo[name] = dict(acc)
        return memo[name]

    entry = None
    for line in hlo_text.splitlines():
        m = re.match(r"\s*ENTRY\s+%?([\w.\-]+)", line)
        if m:
            entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # conservative: sum everything once
        agg = defaultdict(int)
        for body in comps.values():
            for k, v in _direct_bytes(body).items():
                agg[k] += v
        return dict(agg)
    return total(entry)


def count_ops(hlo_text: str, name: str) -> int:
    return len(re.findall(r"\b" + re.escape(name) + r"\(", hlo_text))

"""Failure-domain chaos layer: deterministic fault injection + typed
recovery primitives.

EPD disaggregation multiplies failure domains: the E->P feature store
can lose entries, the P->D transfer fabric can drop a group's handshake
or its wire payload, a Decode instance can vanish mid-stream, and the
host swap tier can lose a preempted request's pages. This module is the
single fault *plane* across all of them:

* :class:`FaultPlan` / :class:`FaultInjector` — a seeded, deterministic
  description of which faults fire where. Faults arm at named *sites*
  (``SITE_*`` constants); each site supports a per-check probability
  (``rates``), explicitly armed one/multi-shot faults (``armed``), and a
  per-site total cap (``max_faults``). Every decision is a pure function
  of ``(seed, site, key, attempt)`` — replaying the same plan against
  the same call keys reproduces the same faults bit-for-bit, regardless
  of call order across sites. That is what makes chaos sweeps, CI smoke
  jobs, and "outputs bit-identical to the zero-fault run" acceptance
  tests possible.

* :class:`RetryPolicy` — typed retry/backoff: bounded attempts, capped
  exponential backoff with *seeded* jitter (deterministic per
  ``(seed, key, attempt)``), and a per-request retry-time deadline. The
  recovery arms (store refetch, transfer re-handshake/resend, swap
  re-fault) charge its delays through the CostModel into simulator and
  cluster latency accounting, so recovery is never free.

* the typed error hierarchy — :class:`FaultError` and its subclasses
  (:class:`TransferError`, :class:`StoreMiss`, :class:`InstanceDown`,
  :class:`SwapLost`, :class:`NoFreeSlot`, :class:`PlanError`), joining
  the existing ``serving.kv_pool.PoolExhausted`` precedent: recovery
  code dispatches on types and typed fields, never on message text.
  Everything subclasses RuntimeError (PlanError additionally
  ValueError) so pre-existing ``except RuntimeError`` / string-match
  callers keep working.

Recovery arms per failure domain (who consumes this module):

=====================  ====================================================
failure domain          recovery arm
=====================  ====================================================
store.fetch            retry w/ backoff, then §3.2 local recompute
                       (``EPDCluster.prefill`` / ``EPPrefetcher``)
transfer.handshake /   per-group re-handshake/resend w/ backoff, then a
transfer.wire          fresh grouped plan for only the missing groups
                       (``kv_transfer.recover_plan``)
decode.crash           cross-instance re-route: re-prefill rides the
                       prefix cache, decode resumes at the exact position
                       (``EPDCluster``)
swap.in                radix re-match + suffix recompute of the lost
                       private pages (``Engine._resume`` re-fault path)
=====================  ====================================================
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional

# ---------------------------------------------------------------------------
# Fault sites
# ---------------------------------------------------------------------------

SITE_STORE_FETCH = "store.fetch"            # MM-store feature fetch loss
SITE_TRANSFER_HANDSHAKE = "transfer.handshake"  # P->D group handshake drop
SITE_TRANSFER_WIRE = "transfer.wire"        # P->D group wire/payload loss
SITE_DECODE_CRASH = "decode.crash"          # decode instance dies mid-stream
SITE_SWAP_IN = "swap.in"                    # host swap tier loses a handle

SITES = frozenset({SITE_STORE_FETCH, SITE_TRANSFER_HANDSHAKE,
                   SITE_TRANSFER_WIRE, SITE_DECODE_CRASH, SITE_SWAP_IN})


# ---------------------------------------------------------------------------
# Typed error hierarchy
# ---------------------------------------------------------------------------

class FaultError(RuntimeError):
    """Base of the typed failure-domain errors. Subclasses RuntimeError
    so legacy ``except RuntimeError`` recovery paths keep catching; new
    code dispatches on the subclass and its typed fields instead of
    message text (the ``PoolExhausted`` precedent)."""

    site: str = ""


class TransferError(FaultError):
    """A P->D transfer group could not be delivered within the retry
    policy (handshake or wire faults exhausted every attempt, including
    the fresh-replan fallback)."""

    def __init__(self, site: str, group: int, attempts: int):
        self.site = site
        self.group = int(group)
        self.attempts = int(attempts)
        super().__init__(
            f"transfer group {group} lost at {site} after "
            f"{attempts} attempts")


class StoreMiss(FaultError):
    """A keyed MM-store fetch found no (or a faulted) entry. The typed
    arm: retry per policy, then take the §3.2 local-recompute path."""

    site = SITE_STORE_FETCH

    def __init__(self, key: str, attempts: int = 1):
        self.key = key
        self.attempts = int(attempts)
        super().__init__(
            f"MM store miss for key {key!r} after {attempts} attempts")


class InstanceDown(FaultError):
    """A serving instance (typically Decode) crashed / left the cluster.
    Recovery re-routes its in-flight requests to a surviving instance."""

    site = SITE_DECODE_CRASH

    def __init__(self, instance: str, n_requests: int = 0):
        self.instance = str(instance)
        self.n_requests = int(n_requests)
        super().__init__(
            f"instance {instance} down ({n_requests} in-flight requests)")


class SwapLost(FaultError):
    """The host swap tier lost (or corrupted) a preempted request's
    pages: the handle is consumed and the KV content is gone. Recovery
    re-faults via radix re-match + suffix recompute from the request's
    known token sequence."""

    site = SITE_SWAP_IN

    def __init__(self, handle_id: int, n_pages: int):
        self.handle_id = int(handle_id)
        self.n_pages = int(n_pages)
        super().__init__(
            f"swap handle {handle_id} lost ({n_pages} pages of KV "
            f"unrecoverable from host store)")


class NoFreeSlot(FaultError):
    """Decode admission found no free batch slot (typed replacement for
    the string-raised RuntimeError; the message is kept verbatim for
    legacy ``match=`` callers)."""

    def __init__(self, msg: str = "no free decode slot"):
        super().__init__(msg)


class PlanError(FaultError, ValueError):
    """Invalid transfer-plan input (negative/zero bytes, empty segment
    lists, nonpositive group sizes/bandwidth). Subclasses ValueError so
    legacy ``except ValueError`` callers keep working."""


# ---------------------------------------------------------------------------
# Deterministic fault plane
# ---------------------------------------------------------------------------

def _unit(seed: int, site: str, key: Any, attempt: int) -> float:
    """Uniform [0, 1) draw that is a pure function of its arguments —
    stable across processes and call order (sha256, not ``hash``)."""
    blob = repr((int(seed), site, key, int(attempt))).encode()
    h = hashlib.sha256(blob).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


@dataclass
class ArmedFault:
    """One explicitly scheduled fault: fires on the next ``count``
    checks of ``site`` whose key matches (``key=None`` matches any)."""

    site: str
    key: Any = None
    count: int = 1


@dataclass
class FaultPlan:
    """Declarative, seeded fault schedule (the serializable config the
    chaos suite and benchmarks pin).

    seed        — drives every probabilistic draw and all backoff jitter.
    rates       — site -> per-check fault probability in [0, 1].
    armed       — explicit one/multi-shot faults (see ArmedFault).
    max_faults  — site -> cap on total *rate-based* fires (armed faults
                  are already counted); 0/absent = uncapped.
    """

    seed: int = 0
    rates: Dict[str, float] = field(default_factory=dict)
    armed: List[ArmedFault] = field(default_factory=list)
    max_faults: Dict[str, int] = field(default_factory=dict)

    def validate(self) -> "FaultPlan":
        for site, r in self.rates.items():
            if site not in SITES:
                raise PlanError(f"unknown fault site {site!r} "
                                f"(known: {sorted(SITES)})")
            if not (0.0 <= r <= 1.0):
                raise PlanError(f"fault rate for {site} must be in "
                                f"[0, 1], got {r}")
        for a in self.armed:
            if a.site not in SITES:
                raise PlanError(f"unknown fault site {a.site!r}")
            if a.count < 1:
                raise PlanError(f"armed fault count must be >= 1, "
                                f"got {a.count}")
        for site, n in self.max_faults.items():
            if site not in SITES:
                raise PlanError(f"unknown fault site {site!r}")
            if n < 0:
                raise PlanError(f"max_faults[{site}] must be >= 0")
        return self


@dataclass
class FaultStats:
    checks: Dict[str, int] = field(default_factory=dict)
    fired: Dict[str, int] = field(default_factory=dict)

    def record(self, site: str, fired: bool) -> None:
        self.checks[site] = self.checks.get(site, 0) + 1
        if fired:
            self.fired[site] = self.fired.get(site, 0) + 1

    def n_fired(self, site: Optional[str] = None) -> int:
        if site is not None:
            return self.fired.get(site, 0)
        return sum(self.fired.values())


class FaultInjector:
    """Runtime half of the fault plane: subsystems ask
    ``should_fail(site, key, attempt)`` at their instrumented sites and
    get deterministic answers.

    Armed faults fire first (matched by key, decremented per fire);
    probabilistic faults draw from ``_unit(seed, site, key, attempt)``
    so a *retry* of the same operation (attempt+1) re-draws — transient
    faults can heal under retry, which is what the backoff arms exploit.
    """

    def __init__(self, plan: Optional[FaultPlan] = None, metrics=None):
        self.plan = (plan or FaultPlan()).validate()
        self._armed: List[ArmedFault] = [replace(a) for a in self.plan.armed]
        self._rate_fired: Dict[str, int] = {}
        self.stats = FaultStats()
        # optional telemetry registry (core.telemetry.MetricsRegistry):
        # mirrors FaultStats into labeled counters so chaos runs show up
        # in the unified metrics snapshot. Duck-typed to avoid an import
        # cycle (telemetry must stay dependency-free).
        self.metrics = metrics

    def _record(self, site: str, fired: bool) -> None:
        self.stats.record(site, fired)
        if self.metrics is not None:
            self.metrics.counter("fault_checks_total", site=site).inc()
            if fired:
                self.metrics.counter("faults_fired_total", site=site).inc()

    # -- arming (the MMStore.inject_fault generalization) --------------------
    def arm(self, site: str, key: Any = None, count: int = 1) -> None:
        """Explicitly schedule ``count`` faults at ``site`` for checks
        matching ``key`` (None = any). Multi-shot and per-site, unlike
        the legacy one-shot ``MMStore.inject_fault`` it generalizes."""
        if site not in SITES:
            raise PlanError(f"unknown fault site {site!r}")
        if count < 1:
            raise PlanError(f"armed fault count must be >= 1, got {count}")
        self._armed.append(ArmedFault(site, key, count))

    @property
    def armed_remaining(self) -> int:
        return sum(a.count for a in self._armed)

    # -- the decision point ---------------------------------------------------
    def should_fail(self, site: str, key: Any = None,
                    attempt: int = 0) -> bool:
        if site not in SITES:
            raise PlanError(f"unknown fault site {site!r}")
        for a in self._armed:
            if a.site == site and (a.key is None or a.key == key):
                a.count -= 1
                if a.count <= 0:
                    self._armed.remove(a)
                self._record(site, True)
                return True
        rate = self.plan.rates.get(site, 0.0)
        if rate > 0.0:
            cap = self.plan.max_faults.get(site, 0)
            if not cap or self._rate_fired.get(site, 0) < cap:
                if _unit(self.plan.seed, site, key, attempt) < rate:
                    self._rate_fired[site] = \
                        self._rate_fired.get(site, 0) + 1
                    self._record(site, True)
                    return True
        self._record(site, False)
        return False

    def n_fired(self, site: Optional[str] = None) -> int:
        return self.stats.n_fired(site)


# ---------------------------------------------------------------------------
# Typed retry/backoff policy
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with capped exponential backoff and seeded jitter.

    max_attempts — total tries including the first (1 = no retry).
    backoff_base — delay before the first retry, seconds.
    backoff_mult — exponential growth per further retry.
    backoff_cap  — upper bound on any single backoff delay.
    jitter       — +/- fraction of the delay, drawn deterministically
                   from (seed, key, attempt) so schedules replay.
    deadline     — per-request budget of *cumulative retry time*
                   (backoffs + wasted attempts); recovery escalates to
                   the next arm (replan / recompute / re-route) once the
                   budget is spent instead of retrying forever.
    """

    max_attempts: int = 4
    backoff_base: float = 2e-3
    backoff_mult: float = 2.0
    backoff_cap: float = 50e-3
    jitter: float = 0.1
    deadline: float = math.inf
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise PlanError(f"max_attempts must be >= 1, "
                            f"got {self.max_attempts}")
        if self.backoff_base < 0 or self.backoff_cap < 0:
            raise PlanError("backoff_base/backoff_cap must be >= 0")
        if self.backoff_mult < 1.0:
            raise PlanError(f"backoff_mult must be >= 1, "
                            f"got {self.backoff_mult}")
        if not (0.0 <= self.jitter <= 1.0):
            raise PlanError(f"jitter must be in [0, 1], got {self.jitter}")
        if self.deadline < 0:
            raise PlanError(f"deadline must be >= 0, got {self.deadline}")

    def backoff(self, attempt: int, key: Any = None) -> float:
        """Delay before retry number ``attempt`` (1-based: the wait
        after the ``attempt``-th failure), capped, with seeded jitter."""
        if attempt < 1:
            raise PlanError(f"backoff attempt must be >= 1, got {attempt}")
        d = min(self.backoff_cap,
                self.backoff_base * self.backoff_mult ** (attempt - 1))
        if self.jitter:
            u = _unit(self.seed, "retry.jitter", key, attempt)
            d *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return d

    def next_retry_at(self, now: float, attempt: int,
                      key: Any = None) -> Optional[float]:
        """Non-blocking variant of :meth:`backoff`: the absolute clock
        time retry number ``attempt`` may run, or None when the policy
        is exhausted (``attempt`` would exceed ``max_attempts`` — the
        caller escalates to the next recovery arm instead of parking).
        A scheduler parks the failed operation with this clock as a
        ``retry_at`` barrier and composes the plan around it, rather
        than sleeping through the backoff in a synchronous retry loop."""
        if attempt >= self.max_attempts:
            return None
        return now + self.backoff(attempt, key=key)

    def worst_case_retry_time(self) -> float:
        """Upper bound on the cumulative backoff of one operation —
        what a latency SLO must absorb per recovery (benchmarks assert
        TTFT inflation stays within a small multiple of this)."""
        t = sum(min(self.backoff_cap,
                    self.backoff_base * self.backoff_mult ** (a - 1))
                * (1.0 + self.jitter)
                for a in range(1, self.max_attempts))
        return min(t, self.deadline)


DEFAULT_RETRY = RetryPolicy()
NO_RETRY = RetryPolicy(max_attempts=1)

"""Kernel / engine microbenchmarks (CPU-executable path).

Times the jnp reference implementations (the CPU stand-ins for the Pallas
kernels — the kernels themselves only run for real on TPU; interpret mode
timing is meaningless) and the end-to-end engine steps on reduced configs.
Rows: name,us_per_call,derived.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_kernels() -> List[str]:
    from repro.kernels.decode_attention.ref import decode_attention_ref
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.ssd_scan.ref import ssd_ref

    rows = ["kernel,us_per_call,derived"]
    key = jax.random.PRNGKey(0)

    b, s, nq, nkv, hd = 2, 512, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, nq, hd))
    k = jax.random.normal(ks[1], (b, s, nkv, hd))
    v = jax.random.normal(ks[2], (b, s, nkv, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    fa = jax.jit(lambda *a: attention_ref(*a))
    us = _time(fa, q, k, v, pos, pos)
    flops = 4 * b * nq * s * (s / 2) * hd
    rows.append(f"flash_attention_ref_b{b}_s{s},{us:.0f},"
                f"{flops / us / 1e3:.1f}_gflops")

    S = 4096
    kd = jax.random.normal(ks[1], (b, S, nkv, hd))
    vd = jax.random.normal(ks[2], (b, S, nkv, hd))
    kp = jnp.broadcast_to(jnp.arange(S), (b, S))
    qd = jax.random.normal(ks[0], (b, nq, hd))
    qp = jnp.array([S - 1] * b)
    da = jax.jit(lambda *a: decode_attention_ref(*a))
    us = _time(da, qd, kd, vd, qp, kp)
    kv_bytes = b * S * nkv * hd * 2 * 4
    rows.append(f"decode_attention_ref_b{b}_S{S},{us:.0f},"
                f"{kv_bytes / us / 1e3:.1f}_GBps_kvread")

    B, L, H, P, N, chunk = 2, 1024, 4, 64, 64, 128
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    bm = jax.random.normal(ks[3], (B, L, N)) * 0.3
    cm = jax.random.normal(ks[4], (B, L, N)) * 0.3
    dsk = jnp.ones((H,))
    sf = jax.jit(lambda *args: ssd_ref(*args, chunk))
    us = _time(sf, x, dt, a, bm, cm, dsk)
    rows.append(f"ssd_scan_ref_B{B}_L{L},{us:.0f},"
                f"{B * L / us:.2f}_tokens_per_us")
    return rows


def bench_engine() -> List[str]:
    from repro.configs import get_config
    from repro.models.model import init_params
    from repro.serving.engine import Engine
    from repro.serving.request import Request

    rows = ["engine,us_per_call,derived"]
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=4, max_len=64)
    reqs = [Request(prompt_tokens=list(range(2, 10)), max_new_tokens=50)
            for _ in range(4)]
    t0 = time.perf_counter()
    for r in reqs:
        first, caches = eng.prefill_request(r)
        eng.insert(r, caches, first)
    t_pre = (time.perf_counter() - t0) / len(reqs) * 1e6
    rows.append(f"engine_prefill_insert,{t_pre:.0f},batch1_len8")
    n = 0
    t0 = time.perf_counter()
    while eng.n_active:
        eng.decode_step()
        n += 1
    t_dec = (time.perf_counter() - t0) / max(n, 1) * 1e6
    rows.append(f"engine_decode_step,{t_dec:.0f},batch4_{n}_iters")
    return rows

"""P->D hierarchical grouped KV-cache transmission (paper §3.3).

Three schemes, matching the paper's ablation:

* ``one_shot``   — transfer the whole KV cache after Prefill completes
  (the naive PD-disaggregation baseline; fully exposed).
* ``layer_wise`` — layer L's KV ships while layer L+1 computes, but every
  per-layer transfer pays a *blocking* metadata handshake with the Decode
  side: the handshake sits in the compute stream, stalling the pipeline
  and misaligning communication with computation (paper Fig. 7a/c —
  overlap ratios of only 15-25%).
* ``grouped``    — adjacent layers' KV packed into groups (one handshake
  per group, performed asynchronously off an event queue), with
  delayed-start scheduling so each group's wire time hides under the
  compute of the remaining layers (paper Fig. 7b/d — ~99% overlap, and
  higher effective bandwidth because handshakes are amortized over
  larger payloads).

The planner is deterministic and separately unit-tested; both the
simulator and the real mini-cluster runner call :func:`plan`.

:func:`plan_chunked` is the CHUNKED-prefill variant (streaming P->D):
prefill runs in fixed-size token chunks and chunk *k*'s pages (all
layers, one grouped handshake) ride the link while chunk *k+1* computes.
A cached-prefix segment (zero compute) can ship immediately at t=0. The
only exposed latency is the final chunk's tail — for long prompts this
replaces the serialized prefill-then-transfer TTFT with
max(prefill, transfer) + last-chunk tail.

Metric definitions (paper Table 4):
  kv_latency  — total time the transfer machinery is busy (handshakes +
                wire) for this request's KV.
  exposed     — part of that latency on the request's critical path
                (compute stalls + completion past prefill end).
  overlap     — 1 - exposed / kv_latency.
  effective_bandwidth — payload / kv_latency.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, List, Literal, Optional, Tuple

from repro.core.faults import (SITE_TRANSFER_HANDSHAKE, SITE_TRANSFER_WIRE,
                               FaultInjector, PlanError, RetryPolicy,
                               TransferError)

Scheme = Literal["one_shot", "layer_wise", "grouped", "chunked"]


@dataclass(frozen=True)
class GroupPlan:
    """One transmission unit: layers [start, end) (prefill chunks
    [start, end) for the "chunked" scheme)."""
    start: int
    end: int
    nbytes: float
    t_ready: float        # when the last layer of the group finishes compute
    t_send: float         # scheduled send start (after handshake)
    t_done: float         # transfer completion


@dataclass
class TransferPlan:
    scheme: Scheme
    groups: List[GroupPlan]
    prefill_time: float            # compute-only prefill duration
    prefill_end: float             # actual prefill end incl. blocking stalls
    kv_latency: float
    exposed_latency: float
    effective_bandwidth: float

    @property
    def overlap_ratio(self) -> float:
        if self.kv_latency <= 0:
            return 1.0
        return max(0.0, 1.0 - self.exposed_latency / self.kv_latency)

    @property
    def total_done(self) -> float:
        """When the Decode instance holds the full KV (TTFT gate)."""
        return max((g.t_done for g in self.groups), default=self.prefill_end)


def choose_group_size(n_layers: int, per_layer_compute: float,
                      handshake: float, per_layer_transfer: float) -> int:
    """Paper §3.3: group size from compute load vs. handshake latency.

    A group of g layers keeps the link busy for (handshake + g*wire) while
    compute advances g*t_c. To keep the link from falling behind when
    compute is the slower side we need handshake + g*t_x <= g*t_c, i.e.
    g >= handshake / (t_c - t_x). When the wire is slower than compute no
    g keeps up; amortize the handshake to <2% of wire time instead.
    """
    if n_layers <= 1:
        return 1
    t_c, t_x = per_layer_compute, per_layer_transfer
    if t_c > t_x:
        # compute-bound: the link must keep up with compute even though
        # each group pays one handshake: h + g*t_x <= g*t_c
        g = math.ceil(handshake / max(t_c - t_x, 1e-12))
    else:
        # wire-bound: the link is saturated, so completion ~=
        # g*t_c (first group's readiness delay) + (n/g)*h (handshakes)
        # + n*t_x (payload). Minimizing over g: g* = sqrt(n*h/t_c).
        g = round(math.sqrt(n_layers * handshake / max(t_c, 1e-12)))
    return max(1, min(g, max(n_layers // 2, 1)))


def plan(scheme: Scheme, *, n_layers: int, bytes_per_layer: float,
         per_layer_compute: float, handshake: float, link_bw: float,
         group_size: int = 0, page_bytes: float = 0.0) -> TransferPlan:
    """Build the transmission schedule for one request's KV cache.

    page_bytes > 0 switches to page-granular transmission (paged KV
    pools): each layer's payload is rounded up to whole pages, so every
    group's bytes are page-aligned — transfers map 1:1 onto pool pages
    on both ends and the wire never ships a partial page. The padding
    cost of the last partial page is thereby made explicit in the
    schedule instead of hidden in the runtime.

    Invalid inputs raise :class:`~repro.core.faults.PlanError` (a
    ValueError): a malformed plan request is a caller bug, not a
    schedulable transfer, and must never half-build a schedule.
    """
    if n_layers <= 0:
        raise PlanError(f"n_layers must be >= 1, got {n_layers}")
    if bytes_per_layer <= 0:
        raise PlanError(
            f"bytes_per_layer must be positive, got {bytes_per_layer}")
    if per_layer_compute < 0:
        raise PlanError(
            f"per_layer_compute must be >= 0, got {per_layer_compute}")
    if handshake < 0:
        raise PlanError(f"handshake must be >= 0, got {handshake}")
    if link_bw <= 0:
        raise PlanError(f"link_bw must be positive, got {link_bw}")
    if group_size < 0:
        raise PlanError(
            f"group_size must be >= 0 (0 = auto), got {group_size}")
    if page_bytes < 0:
        raise PlanError(f"page_bytes must be >= 0, got {page_bytes}")
    t_c = per_layer_compute
    if page_bytes > 0:
        bytes_per_layer = math.ceil(bytes_per_layer / page_bytes) * page_bytes
    t_x = bytes_per_layer / link_bw
    prefill_time = n_layers * t_c
    payload = n_layers * bytes_per_layer

    if scheme == "one_shot":
        t0 = prefill_time
        busy = handshake + payload / link_bw
        g = GroupPlan(0, n_layers, payload, t0, t0 + handshake, t0 + busy)
        return TransferPlan(scheme, [g], prefill_time, prefill_time,
                            busy, busy, payload / busy)

    if scheme == "layer_wise":
        # Blocking handshake in the compute stream: layer l's compute ends,
        # then the host handshake stalls the pipeline for `handshake`
        # before the (async) wire transfer starts.
        groups: List[GroupPlan] = []
        clock = 0.0          # compute-stream time
        link_free = 0.0
        stalls = 0.0
        for l in range(n_layers):
            clock += t_c                      # layer l computes
            clock += handshake                # blocking metadata handshake
            stalls += handshake
            t_send = max(clock, link_free)
            t_done = t_send + t_x
            groups.append(GroupPlan(l, l + 1, bytes_per_layer,
                                    clock - handshake, t_send, t_done))
            link_free = t_done
        prefill_end = clock
        total_done = groups[-1].t_done
        kv_latency = stalls + n_layers * t_x
        exposed = stalls + max(0.0, total_done - prefill_end)
        eff_bw = payload / kv_latency
        return TransferPlan(scheme, groups, prefill_time, prefill_end,
                            kv_latency, exposed, eff_bw)

    # ---- grouped: async handshakes off the event queue, aligned start ----
    # One handshake per group rides the link (never the compute stream —
    # that's the layer-wise pathology), so handshake cost is amortized over
    # the group's payload. The final group is tapered to a single layer so
    # the unavoidable tail (the last layer's KV, which no compute can
    # hide) is minimal.
    gsz = group_size or choose_group_size(n_layers, t_c, handshake, t_x)
    if gsz > 1 and n_layers > gsz:
        body = [gsz] * ((n_layers - 1) // gsz)
        rest = (n_layers - 1) - sum(body)
        sizes = body + ([rest] if rest else []) + [1]
    else:
        sizes = [gsz] * (n_layers // gsz)
        if n_layers % gsz:
            sizes.append(n_layers % gsz)

    groups = []
    start = 0
    link_free = 0.0
    busy = 0.0
    for sz in sizes:
        end = start + sz
        nbytes = sz * bytes_per_layer
        t_ready = end * t_c
        t_send = max(t_ready, link_free) + handshake
        t_done = t_send + nbytes / link_bw
        groups.append(GroupPlan(start, end, nbytes, t_ready, t_send, t_done))
        link_free = t_done
        busy += handshake + nbytes / link_bw
        start = end
    total_done = groups[-1].t_done
    exposed = max(0.0, total_done - prefill_time)
    eff_bw = payload / busy
    return TransferPlan("grouped", groups, prefill_time, prefill_time,
                        busy, exposed, eff_bw)


def plan_chunked(*, chunk_bytes: List[float], chunk_compute: List[float],
                 handshake: float, link_bw: float,
                 page_bytes: float = 0.0) -> TransferPlan:
    """Streaming transfer schedule for a CHUNKED prefill.

    ``chunk_bytes[k]`` — KV bytes of segment *k* across ALL layers;
    ``chunk_compute[k]`` — that segment's prefill compute time (0 for a
    segment already resident, e.g. a prefix-cache hit, whose pages can
    ship before any compute). Segment *k*'s transfer is one grouped unit
    (single async handshake) eligible to start the moment its compute
    finishes, so it rides the link while segments k+1.. compute. Empty
    (zero-byte) segments emit no group and pay no handshake, but their
    compute still advances the clock.

    ``page_bytes`` > 0 rounds every segment up to whole KV-pool pages
    (here the quantum is a FULL page across all layers — chunk payloads
    map 1:1 onto pool pages, unlike the per-layer slices of
    :func:`plan`).
    """
    if len(chunk_bytes) != len(chunk_compute):
        raise PlanError(
            f"{len(chunk_bytes)} byte segments vs "
            f"{len(chunk_compute)} compute segments")
    if not chunk_bytes:
        raise PlanError("empty segment list: nothing to plan")
    if any(b < 0 for b in chunk_bytes):
        raise PlanError(f"negative segment bytes in {chunk_bytes}")
    if any(t < 0 for t in chunk_compute):
        raise PlanError(f"negative segment compute in {chunk_compute}")
    if handshake < 0:
        raise PlanError(f"handshake must be >= 0, got {handshake}")
    if link_bw <= 0:
        raise PlanError(f"link_bw must be positive, got {link_bw}")
    if page_bytes < 0:
        raise PlanError(f"page_bytes must be >= 0, got {page_bytes}")
    groups: List[GroupPlan] = []
    clock = 0.0                        # compute-stream time
    link_free = 0.0
    busy = 0.0
    payload = 0.0
    for k, (nbytes, t_c) in enumerate(zip(chunk_bytes, chunk_compute)):
        clock += t_c
        if page_bytes > 0 and nbytes > 0:
            nbytes = math.ceil(nbytes / page_bytes) * page_bytes
        if nbytes <= 0:
            continue
        t_send = max(clock, link_free) + handshake
        t_done = t_send + nbytes / link_bw
        groups.append(GroupPlan(k, k + 1, nbytes, clock, t_send, t_done))
        link_free = t_done
        busy += handshake + nbytes / link_bw
        payload += nbytes
    prefill_end = sum(chunk_compute)
    total_done = max((g.t_done for g in groups), default=prefill_end)
    exposed = max(0.0, total_done - prefill_end)
    eff_bw = payload / busy if busy > 0 else 0.0
    return TransferPlan("chunked", groups, prefill_end, prefill_end,
                        busy, exposed, eff_bw)


# ---------------------------------------------------------------------------
# Fault recovery: re-handshake/resend with backoff + fresh replan of
# only the missing groups
# ---------------------------------------------------------------------------

@dataclass
class TransferRecovery:
    """What it took to deliver a plan through an injected fault field."""

    handshake_faults: int = 0
    wire_faults: int = 0
    retries: int = 0              # failed attempts that were retried
    retry_time: float = 0.0       # backoff + wasted handshake/wire time
    replanned_groups: int = 0     # groups delivered via the fresh replan
    deadline_hits: int = 0        # groups whose retry budget ran out
    # link-time event log for the span tracer: (kind, group_start,
    # t_begin, t_end) — wasted attempts and backoff idles, in the same
    # relative timebase as the recovered plan's group schedule.
    events: List[Tuple[str, int, float, float]] = field(default_factory=list)

    @property
    def faults(self) -> int:
        return self.handshake_faults + self.wire_faults


def _attempt_group(g: GroupPlan, clock: float, *, injector: FaultInjector,
                   policy: RetryPolicy, handshake: float, link_bw: float,
                   key: Any, tag: str, rec: TransferRecovery,
                   retry_spent: float) -> Tuple[Optional[GroupPlan], float,
                                                float]:
    """Try to deliver one group starting at link time ``clock``.

    Returns (delivered group or None, new link clock, retry time spent).
    A failed handshake wastes its handshake latency; a failed wire
    transfer wastes handshake + wire (the payload is resent whole —
    partial-delivery resume is below the planning granularity). Between
    attempts the link idles for the policy's seeded backoff. ``None``
    means every attempt (or the retry-time deadline) was exhausted."""
    wire = g.nbytes / link_bw
    t = max(clock, g.t_ready)
    for a in range(1, policy.max_attempts + 1):
        hs_fail = injector.should_fail(
            SITE_TRANSFER_HANDSHAKE, key=(key, tag, g.start), attempt=a)
        wire_fail = (not hs_fail) and injector.should_fail(
            SITE_TRANSFER_WIRE, key=(key, tag, g.start), attempt=a)
        if not hs_fail and not wire_fail:
            done = t + handshake + wire
            return (replace(g, t_send=t + handshake, t_done=done),
                    done, retry_spent)
        wasted = handshake if hs_fail else handshake + wire
        if hs_fail:
            rec.handshake_faults += 1
        else:
            rec.wire_faults += 1
        rec.events.append(("kv.retry.wasted", g.start, t, t + wasted))
        t += wasted
        retry_spent += wasted
        rec.retry_time += wasted
        if a < policy.max_attempts:
            if retry_spent >= policy.deadline:
                rec.deadline_hits += 1
                return None, t, retry_spent
            back = policy.backoff(a, key=(key, tag, g.start))
            rec.events.append(("kv.retry.backoff", g.start, t, t + back))
            t += back
            retry_spent += back
            rec.retry_time += back
            rec.retries += 1
    return None, t, retry_spent


def recover_plan(plan: TransferPlan, *, injector: FaultInjector,
                 policy: RetryPolicy, handshake: float, link_bw: float,
                 key: Any = None,
                 replan: bool = True) -> Tuple[TransferPlan,
                                               TransferRecovery]:
    """Re-schedule ``plan`` under the injector's transfer-fault field.

    Layered recovery, per group and in link order:

    1. re-handshake/resend with the policy's capped, seeded backoff —
       transient handshake or wire faults heal in place;
    2. groups that exhaust their attempts (or the per-request retry-time
       deadline) fall back to a *fresh grouped plan covering only the
       missing groups*, appended after the survivors (one new handshake
       each, a fresh attempt budget — the §3.3 grouped machinery reused
       as the repair path);
    3. a group the replan also cannot deliver raises
       :class:`TransferError` — with ``replan=False`` and
       ``policy=NO_RETRY`` that is the recovery-off baseline, where any
       fault loses the request.

    The recovered plan keeps the original compute timeline
    (``prefill_time`` / ``prefill_end``) — faults cost link time and
    backoff, never compute — so TTFT inflation shows up purely in
    ``exposed_latency`` / ``total_done``, which is exactly where the
    simulator and cluster charge it. Payload is conserved: every
    original group is delivered exactly once (possibly late)."""
    if link_bw <= 0:
        raise PlanError(f"link_bw must be positive, got {link_bw}")
    if handshake < 0:
        raise PlanError(f"handshake must be >= 0, got {handshake}")
    rec = TransferRecovery()
    delivered: List[GroupPlan] = []
    missing: List[GroupPlan] = []
    clock = 0.0
    spent = 0.0
    for g in plan.groups:
        got, clock, spent = _attempt_group(
            g, clock, injector=injector, policy=policy, handshake=handshake,
            link_bw=link_bw, key=key, tag="xfer", rec=rec, retry_spent=spent)
        if got is None:
            missing.append(g)
        else:
            delivered.append(got)
    if missing:
        if not replan:
            raise TransferError(SITE_TRANSFER_WIRE, missing[0].start,
                                policy.max_attempts)
        # fresh grouped plan for ONLY the missing groups: new handshakes,
        # fresh attempt budgets, scheduled after the surviving traffic
        rec.replanned_groups = len(missing)
        for g in missing:
            got, clock, spent = _attempt_group(
                g, clock, injector=injector, policy=policy,
                handshake=handshake, link_bw=link_bw, key=key,
                tag="replan", rec=rec, retry_spent=0.0)
            if got is None:
                raise TransferError(SITE_TRANSFER_WIRE, g.start,
                                    2 * policy.max_attempts)
            delivered.append(got)
    if rec.faults == 0:
        return plan, rec            # zero-fault fast path: plan unchanged
    total_done = max(g.t_done for g in delivered)
    kv_latency = plan.kv_latency + rec.retry_time
    exposed = max(0.0, total_done - plan.prefill_end)
    payload = sum(g.nbytes for g in delivered)
    eff_bw = payload / kv_latency if kv_latency > 0 else 0.0
    out = TransferPlan(plan.scheme, delivered, plan.prefill_time,
                       plan.prefill_end, kv_latency, exposed, eff_bw)
    return out, rec


# ---------------------------------------------------------------------------
# Telemetry: render a transfer schedule as trace spans
# ---------------------------------------------------------------------------

def emit_spans(tracer, plan: TransferPlan, *, base: float, handshake: float,
               compute_track: str, link_track: str,
               chunk_compute: Optional[List[float]] = None,
               request_id: Optional[int] = None,
               recovery: Optional[TransferRecovery] = None) -> None:
    """Record a plan's modeled timeline as tracer spans.

    The plan's group schedule is relative to its own t=0 (prefill
    start); ``base`` anchors it on the tracer's clock. Each group gets a
    ``kv.handshake`` span ([t_send - handshake, t_send]) and a
    ``kv.wire`` span ([t_send, t_done]) on ``link_track``, so the
    chunk-k transfer visibly rides under chunk-k+1 compute in the
    exported trace. ``chunk_compute`` (per-segment compute durations)
    additionally renders the modeled compute stream on
    ``compute_track`` — used when the compute itself is modeled (cost
    model / simulator); the real engine's chunk spans come from its own
    wall clock instead. ``recovery`` adds the retry events (wasted
    attempts, backoff idles) as ``kv.retry.*`` spans on the link track,
    making fault-recovery time visible as explicit timeline gaps."""
    if not tracer.enabled:
        return
    if chunk_compute is not None:
        t = base
        for k, dt in enumerate(chunk_compute):
            if dt > 0:
                tracer.add("prefill.chunk", t, t + dt, track=compute_track,
                           request_id=request_id, chunk=k, modeled=True)
            t += dt
    for g in plan.groups:
        if handshake > 0:
            tracer.add("kv.handshake", base + g.t_send - handshake,
                       base + g.t_send, track=link_track,
                       request_id=request_id, group=g.start)
        tracer.add("kv.wire", base + g.t_send, base + g.t_done,
                   track=link_track, request_id=request_id,
                   group=g.start, nbytes=g.nbytes)
    if recovery is not None:
        for kind, grp, t0, t1 in recovery.events:
            tracer.add(kind, base + t0, base + t1, track=link_track,
                       request_id=request_id, group=grp)

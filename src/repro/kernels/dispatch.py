"""Kernel dispatch policy.

``use_pallas()`` decides whether the model routes attention / SSD through
the Pallas kernels. On this CPU container the kernels run in
``interpret=True`` mode (Python emulation — correct but slow), so the
default is the pure-jnp reference path; set ``REPRO_USE_PALLAS=1`` (or on
a real TPU it flips automatically) to exercise the kernels end-to-end.
"""
from __future__ import annotations

import os

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_pallas() -> bool:
    env = os.environ.get("REPRO_USE_PALLAS")
    if env is not None:
        return env not in ("0", "false", "")
    return on_tpu()


def interpret() -> bool:
    """Pallas interpret mode: required anywhere but a real TPU."""
    return not on_tpu()

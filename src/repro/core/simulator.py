"""End-to-end EPD-Serve simulator.

Executes a request trace against a deployment topology on the
discrete-event engine, with:

* modality-aware multi-path routing + least-loaded dispatch (scheduler),
* MM Store dedup + E->P async feature prefetching (ep_prefetch),
* P->D hierarchical grouped KV transmission (kv_transfer),
* physical co-location with operator-level interference (colocation),
* stage service times from the roofline cost model (costmodel).

Instance execution semantics:
* every instance runs ONE task at a time (its own serial stream);
* monolithic instances (TP1/TP2, 'PD', 'EP') put Encode/Prefill tasks and
  decode iterations in one queue — E/P tasks take priority, which is the
  vLLM-style behaviour that starves Decode under load (paper §1);
* co-located instances (same ``coloc_group``) run concurrently but pay
  the interference slowdown for whatever their chip-mates execute;
* Decode runs as back-to-back batched iterations, one token per request
  per iteration (continuous batching).

This is the scale model used for the paper's Tables 2/5 and Figs 8-17;
the REAL-compute path (actual JAX engines wired through the same MM
Store / scheduler / transfer planner) lives in repro.core.cluster.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core import colocation
from repro.core.costmodel import CostModel, Hardware, V5E
from repro.core.deployment import Deployment, parse
from repro.core.ep_prefetch import EPPrefetcher
from repro.core.events import EventLoop
from repro.core.kv_transfer import (plan as kv_plan,
                                    plan_chunked as kv_plan_chunked)
from repro.core.mm_store import MMStore
from repro.core.scheduler import Router
from repro.models.frontend import encode_tokens_for_image
from repro.serving.request import Request


# ---------------------------------------------------------------------------
# Workload
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DatasetSpec:
    name: str
    mm_fraction: float
    resolution: Tuple[int, int]
    text_tokens_mean: float
    output_tokens: int = 64
    unique_images: int = 0        # 0 => every image unique (no dedup hits)
    # shared-prefix workload (system prompts / few-shot templates):
    # each request prepends one of `prefix_groups` shared prefixes of
    # `prefix_tokens` tokens to its (unique) tail. 0 => no shared prefixes.
    prefix_groups: int = 0
    prefix_tokens: int = 0


# paper §4.1
SHAREGPT_4O = DatasetSpec("ShareGPT-4o", 1.0, (802, 652), 9.6)
VISUALWEB = DatasetSpec("VisualWebInstruct", 0.5, (1280, 720), 63.1)


def gen_requests(spec: DatasetSpec, n: int, rate: float,
                 seed: int = 0) -> List[Request]:
    """Poisson arrivals at `rate` req/s; modality mix per the dataset."""
    rng = random.Random(seed)
    reqs = []
    t = 0.0
    mm_tokens = encode_tokens_for_image(spec.resolution)
    for i in range(n):
        t += rng.expovariate(rate)
        is_mm = rng.random() < spec.mm_fraction
        text_len = max(1, int(rng.gauss(spec.text_tokens_mean,
                                        spec.text_tokens_mean * 0.3)))
        payload = None
        ntok = 0
        if is_mm:
            img_id = (rng.randrange(spec.unique_images)
                      if spec.unique_images else i)
            payload = f"{spec.name}-img-{img_id}".encode()
            ntok = mm_tokens
        if spec.prefix_groups:
            g = rng.randrange(spec.prefix_groups)
            prompt = ([1_000_000 + g * spec.prefix_tokens + j
                       for j in range(spec.prefix_tokens)]
                      + [2_000_000 + i * 1024 + j for j in range(text_len)])
        else:
            # per-request-unique tokens: without them every prompt would
            # be a literal prefix of every longer one and a prefix-cache
            # run over a legacy dataset would report phantom hits
            prompt = [2_000_000 + i * 1024 + j for j in range(text_len)]
        reqs.append(Request(
            prompt_tokens=prompt,
            max_new_tokens=spec.output_tokens,
            mm_payload=payload, mm_tokens=ntok, t_arrival=t))
    return reqs


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------

@dataclass
class SimConfig:
    deployment: str = "E-P-D"
    kv_scheme: str = "grouped"          # one_shot | layer_wise | grouped
    ep_async: bool = True
    decode_batch_max: int = 512
    replicas: int = 1
    hw: Hardware = V5E
    kv_page_tokens: int = 0             # paged KV pool page size (0 = dense)
    # per-Prefill-instance radix prefix caches + cache-aware routing;
    # prefill service time then covers only the uncached suffix.
    prefix_cache: bool = False
    cache_aware_routing: bool = True    # False: least-loaded only (ablation)
    # capacity of each pool-less sim tree (tokens, LRU-evicted): models a
    # bounded KV pool and keeps long simulations from growing one radix
    # node per unique prompt tail forever
    prefix_cache_tokens: int = 65536
    # chunked prefill + streaming P->D transfer: prefill runs in
    # fixed-size chunks whose KV ships while the next chunk computes
    # (kv_transfer.plan_chunked); prefill occupancy retires pending
    # tokens chunk by chunk (Router.on_prefill_progress). Each extra
    # chunk costs one launch overhead — the price of streaming.
    chunked_prefill: bool = False
    prefill_chunk_tokens: int = 256


@dataclass
class SimMetrics:
    deployment: str
    n_chips: int
    requests: List[Request]
    makespan: float
    mean_ttft_ms: float
    p99_ttft_ms: float
    mean_tpot_ms: float
    p99_tpot_ms: float
    throughput_tok_s: float            # all output tokens / makespan
    store_hit_rate: float
    ep_overlap_ratio: float
    prefix_hit_rate: float = 0.0       # cached prefill tokens / text tokens

    def slo_attainment(self, ttft_ms: float, tpot_ms: float) -> float:
        ok = sum(r.meets_slo(ttft_ms, tpot_ms) for r in self.requests)
        return ok / len(self.requests)

    def stage_breakdown_ms(self) -> Dict[str, float]:
        """Mean per-stage latency decomposition (production observability:
        shows WHERE the TTFT goes per deployment — queueing vs encode vs
        E->P dispatch vs prefill)."""
        agg: Dict[str, float] = {}
        for r in self.requests:
            for k, v in r.stage_breakdown().items():
                agg[k] = agg.get(k, 0.0) + v * 1e3
        return {k: v / len(self.requests) for k, v in agg.items()}

    def effective_throughput(self, ttft_ms: float, tpot_ms: float,
                             per_chip: bool = True) -> float:
        toks = sum(len(r.output_tokens) for r in self.requests
                   if r.meets_slo(ttft_ms, tpot_ms))
        t = toks / self.makespan if self.makespan > 0 else 0.0
        return t / self.n_chips if per_chip else t


class _Instance:
    def __init__(self, sim: "Simulator", spec):
        self.sim = sim
        self.spec = spec
        self.queue: List[Tuple[str, Request]] = []    # E / P tasks
        self.decode_batch: Dict[int, Tuple[Request, int]] = {}
        self.decode_wait: List[Request] = []
        self.busy = False
        self.running_stage: Optional[str] = None

    # ---- task intake ----
    def enqueue(self, stage: str, req: Request) -> None:
        self.queue.append((stage, req))
        self.sim.router.on_enqueue(self.spec.name, req.total_prompt_len)
        self._kick()

    def join_decode(self, req: Request) -> None:
        if len(self.decode_batch) >= self.sim.cfg.decode_batch_max:
            self.decode_wait.append(req)
            return
        self.decode_batch[req.request_id] = (req, req.max_new_tokens - 1)
        self.sim.router.on_decode_join(self.spec.name)
        self._kick()

    # ---- execution loop ----
    def _kick(self) -> None:
        if not self.busy:
            self._next()

    def _interference(self, stage: str) -> float:
        if self.spec.coloc_group < 0:
            return 1.0
        peers = [i for i in self.sim.instances.values()
                 if i.spec.coloc_group == self.spec.coloc_group
                 and i is not self and i.busy and i.running_stage]
        if not peers:
            return 1.0
        return colocation.stage_slowdown(stage, [p.running_stage for p in peers])

    def _next(self) -> None:
        sim = self.sim
        loop = sim.loop
        if self.queue:
            stage, req = self.queue.pop(0)
            self.busy, self.running_stage = True, stage
            if stage == "E":
                sim.router.on_start(self.spec.name, req.total_prompt_len)
                dur = sim.cost.encode_time(req.mm_tokens, self.spec.chips,
                                           self.spec.tp)
                dur *= self._interference("E")
                req.t_encode_start = loop.now
                loop.after(dur, lambda: self._finish_encode(req))
            else:
                cached = self._prefix_lookup(req)
                chunk_toks = self._chunk_tokens(req, cached)
                inter = self._interference("P")
                req.t_prefill_start = loop.now
                if chunk_toks is None:
                    sim.router.on_start(self.spec.name,
                                        req.total_prompt_len)
                    dur = sim.cost.prefill_time(
                        req.total_prompt_len, self.spec.chips,
                        self.spec.tp, cached_prefix=cached) * inter
                    self._start_prefill(req, dur, cached, None)
                else:
                    # chunk-granular occupancy: the cached prefix
                    # retires immediately, computed tokens retire as
                    # each chunk finishes
                    sim.router.on_start(self.spec.name, cached)
                    times = [t * inter for t in sim.cost.chunk_prefill_times(
                        req.total_prompt_len, chunk_toks, self.spec.chips,
                        self.spec.tp, cached_prefix=cached)]
                    t_end = 0.0
                    name = self.spec.name
                    for c, dt in zip(chunk_toks, times):
                        t_end += dt
                        loop.after(t_end, lambda c=c:
                                   sim.router.on_prefill_progress(name, c))
                    dur = sum(times)
                    self._start_prefill(req, dur, cached,
                                        (chunk_toks, times))
            sim.router.on_busy_until(self.spec.name, loop.now + dur)
        elif self.decode_batch:
            self.busy, self.running_stage = True, "D"
            batch = len(self.decode_batch)
            kv = sum(r.total_prompt_len + len(r.output_tokens)
                     for r, _ in self.decode_batch.values()) / batch
            dur = sim.cost.decode_step_time(batch, kv, self.spec.chips,
                                            self.spec.tp)
            dur *= self._interference("D")
            loop.after(dur, self._finish_decode_iter)
            sim.router.on_busy_until(self.spec.name, loop.now + dur)
        else:
            self.busy, self.running_stage = False, None

    def _chunk_tokens(self, req: Request, cached: float) -> Optional[list]:
        """Computed-token split of this request's prefill into fixed
        chunks, or None when chunked mode is off / the prompt fits in
        one chunk (chunking a single-chunk prompt only adds overhead).
        Mirrors the real engine's fallbacks: multimodal prompts and
        non-attention-only decoders are served monolithically, so the
        sim must not credit them streaming overlap."""
        cfg = self.sim.cfg
        model = self.sim.model
        if not cfg.chunked_prefill:
            return None
        if req.is_multimodal or model.encoder is not None \
                or model.ssm_layers:
            return None
        C = max(1, cfg.prefill_chunk_tokens)
        computed = max(1, int(req.total_prompt_len - cached))
        if computed <= C:
            return None
        out = [C] * (computed // C)
        if computed % C:
            out.append(computed % C)
        return out

    def _prefix_lookup(self, req: Request) -> float:
        """Cached-prefix tokens on THIS instance's radix tree (full pages
        only), recording hit stats and retaining the prompt for future
        requests. 0 for multimodal prompts (token-keyed cache)."""
        sim = self.sim
        cache = sim.router.prefix_caches.get(self.spec.name)
        if cache is None or req.is_multimodal:
            return 0.0
        m = cache.match_and_ref(req.prompt_tokens,
                                cap=len(req.prompt_tokens) - 1)
        cached = (m.n_tokens // cache.page) * cache.page
        cache.insert(req.prompt_tokens)
        sim.prefix_hit_tokens += cached
        sim.prefix_prompt_tokens += len(req.prompt_tokens)
        return float(cached)

    # ---- stage completions ----
    def _finish_encode(self, req: Request) -> None:
        sim = self.sim
        req.t_encode_done = sim.loop.now
        e_block = sim.finish_encode(self, req)
        if e_block > 0:
            sim.loop.after(e_block, self._next)   # sync push blocks E
        else:
            self._next()

    def _start_prefill(self, req: Request, base_dur: float, cached: float,
                       chunked: Optional[tuple]) -> None:
        sim = self.sim
        d_inst = sim.pick_decode_instance(req, prefer=self.spec.name)
        if d_inst is self:
            # fused PD: no transfer
            sim.loop.after(base_dur, lambda: self._finish_prefill(
                req, d_inst, join_delay=0.0))
            return
        if chunked is not None:
            # streaming: chunk k's pages ride the link under chunk k+1's
            # compute; a cached prefix ships at t=0 (zero compute).
            # Segment bytes are token-proportional slices of the SAME
            # kv_bytes total the serialized baseline plans (sliding-
            # window cap + SSM state included), so the A/B compares
            # schedules, not payload models.
            chunk_toks, times = chunked
            total_toks = cached + sum(chunk_toks)
            per_tok = sim.cost.kv_bytes(req.total_prompt_len) / total_toks
            p = kv_plan_chunked(
                chunk_bytes=[cached * per_tok]
                + [c * per_tok for c in chunk_toks],
                chunk_compute=[0.0] + list(times),
                handshake=sim.cfg.hw.handshake,
                link_bw=sim.cfg.hw.link_bw,
                page_bytes=sim.cost.kv_page_bytes())
        else:
            p = kv_plan(sim.cfg.kv_scheme,
                        n_layers=sim.model.n_layers,
                        bytes_per_layer=sim.cost.kv_bytes(
                            req.total_prompt_len) / sim.model.n_layers,
                        per_layer_compute=base_dur / sim.model.n_layers,
                        handshake=sim.cfg.hw.handshake,
                        link_bw=sim.cfg.hw.link_bw,
                        page_bytes=sim.cost.kv_page_bytes_per_layer())
        sim.kv_plans.append(p)
        # layer-wise blocking handshakes stretch prefill itself
        sim.loop.after(p.prefill_end, lambda: self._finish_prefill(
            req, d_inst, join_delay=max(0.0, p.total_done - p.prefill_end)))

    def _finish_prefill(self, req: Request, d_inst: "_Instance",
                        join_delay: float) -> None:
        sim = self.sim

        def emit() -> None:
            # first token gated on the Decode side holding the full KV
            # (kv_transfer's "TTFT gate"): the exposed transfer tail sits
            # on the TTFT critical path, which is what the grouped /
            # chunked streaming schemes shrink
            req.t_first_token = sim.loop.now
            req.output_tokens.append(0)
            if req.max_new_tokens <= 1:
                req.t_done = sim.loop.now
                sim.done.append(req)
            else:
                d_inst.join_decode(req)

        if join_delay > 0:
            sim.loop.after(join_delay, emit)
        else:
            emit()
        self._next()

    def _finish_decode_iter(self) -> None:
        sim = self.sim
        finished = []
        for rid, (req, remaining) in list(self.decode_batch.items()):
            req.output_tokens.append(0)
            remaining -= 1
            if remaining <= 0:
                req.t_done = sim.loop.now
                finished.append(rid)
                sim.done.append(req)
            else:
                self.decode_batch[rid] = (req, remaining)
        for rid in finished:
            del self.decode_batch[rid]
            sim.router.on_decode_leave(self.spec.name)
        while (self.decode_wait and
               len(self.decode_batch) < sim.cfg.decode_batch_max):
            self.join_decode(self.decode_wait.pop(0))
        self._next()


class Simulator:
    def __init__(self, model: ModelConfig, cfg: SimConfig):
        from repro.core.deployment import scale
        self.model = model
        self.cfg = cfg
        dep = parse(cfg.deployment) if isinstance(cfg.deployment, str) \
            else cfg.deployment
        self.deployment = scale(dep, cfg.replicas)
        self.cost = CostModel(model, cfg.hw, page_tokens=cfg.kv_page_tokens)
        self.loop = EventLoop()
        self.router = Router(self.deployment)
        self.store = MMStore()
        self.prefetcher = EPPrefetcher(self.loop, self.store, self.cost,
                                       async_mode=cfg.ep_async)
        self.instances = {s.name: _Instance(self, s)
                          for s in self.deployment.instances}
        self.done: List[Request] = []
        self.kv_plans: list = []
        self.prefix_hit_tokens = 0.0
        self.prefix_prompt_tokens = 0.0
        if cfg.prefix_cache:
            from repro.serving.prefix_cache import PrefixCache
            page = cfg.kv_page_tokens or 16
            self.router.cache_aware = cfg.cache_aware_routing
            for s in self.deployment.instances:
                if s.serves("P"):
                    self.router.register_prefix_cache(
                        s.name,
                        PrefixCache(page,
                                    max_tokens=cfg.prefix_cache_tokens))

    # ---- routing hooks ----
    def pick_decode_instance(self, req: Request, prefer: str) -> _Instance:
        st = self.router.pick("D", self.loop.now, prefer=prefer)
        return self.instances[st.spec.name]

    def submit(self, req: Request) -> None:
        self.loop.at(req.t_arrival, lambda: self._arrive(req))

    def _arrive(self, req: Request) -> None:
        if req.is_multimodal:
            import hashlib
            key = hashlib.sha256(req.mm_payload).hexdigest()
            if self.store.get(key) is not None:   # counts hit/miss stats
                # cross-request reuse: skip Encode entirely (MM Store hit)
                req.t_encode_start = req.t_encode_done = self.loop.now
                self._to_prefill(req, key)
                return
            st = self.router.pick("E", self.loop.now)
            self.instances[st.spec.name].enqueue("E", req)
        else:
            st = self.router.pick("P", self.loop.now, req=req)
            self.instances[st.spec.name].enqueue("P", req)

    def finish_encode(self, inst: _Instance, req: Request) -> float:
        import hashlib
        key = hashlib.sha256(req.mm_payload).hexdigest()
        self.store.put(key, {"tokens": req.mm_tokens},
                       int(self.cost.feature_bytes(req.mm_tokens)))
        return self._to_prefill(req, key, from_instance=inst)

    def _to_prefill(self, req: Request, key: str,
                    from_instance: Optional[_Instance] = None) -> float:
        st = self.router.pick("P", self.loop.now,
                              prefer=(from_instance.spec.name
                                      if from_instance is not None and
                                      from_instance.spec.serves("P") else None),
                              req=req)
        inst = self.instances[st.spec.name]
        if from_instance is inst:
            inst.enqueue("P", req)           # same instance: no transfer
            return 0.0
        sched_hint = max(0.0, st.busy_until - self.loop.now) \
            + 0.001 * st.pending_tokens
        return self.prefetcher.notify(
            req.request_id, key, req.mm_tokens,
            on_ready=lambda _rec: inst.enqueue("P", req),
            scheduling_latency_hint=sched_hint)

    # ---- run ----
    def run(self, requests: List[Request]) -> SimMetrics:
        for r in requests:
            self.submit(r)
        self.loop.run()
        assert len(self.done) == len(requests), \
            f"stuck: {len(self.done)}/{len(requests)} finished"
        ttfts = sorted(r.ttft * 1e3 for r in self.done)
        tpots = sorted(r.tpot * 1e3 for r in self.done)
        makespan = max(r.t_done for r in self.done) - min(
            r.t_arrival for r in self.done)
        toks = sum(len(r.output_tokens) for r in self.done)
        q = lambda xs, p: xs[min(len(xs) - 1, int(p * len(xs)))]
        return SimMetrics(
            deployment=self.deployment.name,
            n_chips=self.deployment.n_chips,
            requests=list(self.done),
            makespan=makespan,
            mean_ttft_ms=sum(ttfts) / len(ttfts),
            p99_ttft_ms=q(ttfts, 0.99),
            mean_tpot_ms=sum(tpots) / len(tpots),
            p99_tpot_ms=q(tpots, 0.99),
            throughput_tok_s=toks / makespan if makespan > 0 else 0.0,
            store_hit_rate=self.store.stats.hit_rate,
            ep_overlap_ratio=self.prefetcher.mean_overlap_ratio,
            prefix_hit_rate=(self.prefix_hit_tokens / self.prefix_prompt_tokens
                             if self.prefix_prompt_tokens else 0.0),
        )


def simulate(model: ModelConfig, deployment: str, dataset: DatasetSpec,
             *, rate: float, n_requests: int = 512, seed: int = 0,
             kv_scheme: str = "grouped", ep_async: bool = True,
             replicas: int = 1, hw: Hardware = V5E,
             per_chip_rate: bool = False,
             kv_page_tokens: int = 0,
             prefix_cache: bool = False,
             cache_aware_routing: bool = True,
             chunked_prefill: bool = False,
             prefill_chunk_tokens: int = 256) -> SimMetrics:
    """Run one deployment against a trace injected at ``rate`` req/s.

    per_chip_rate=True multiplies the rate by the deployment's chip count
    — the paper's figures 8-17 report a per-NPU x-axis so bigger
    deployments absorb proportionally more traffic; Table 5 compares
    deployments at one TOTAL rate (its effective-throughput arithmetic
    only closes under that reading).
    """
    cfg = SimConfig(deployment=deployment, kv_scheme=kv_scheme,
                    ep_async=ep_async, replicas=replicas, hw=hw,
                    kv_page_tokens=kv_page_tokens,
                    prefix_cache=prefix_cache,
                    cache_aware_routing=cache_aware_routing,
                    chunked_prefill=chunked_prefill,
                    prefill_chunk_tokens=prefill_chunk_tokens)
    sim = Simulator(model, cfg)
    if per_chip_rate:
        rate = rate * sim.deployment.n_chips
    reqs = gen_requests(dataset, n_requests, rate, seed)
    return sim.run(reqs)

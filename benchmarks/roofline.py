"""Roofline report: aggregates experiments/dryrun/*.json into the
EXPERIMENTS.md §Roofline table.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh singlepod]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, SHAPES

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load(arch: str, shape: str, mesh: str, label: str = ""):
    suffix = f"_{label}" if label else ""
    f = DRYRUN / f"{arch}_{shape}_{mesh}{suffix}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def fmt_row(r) -> str:
    if r is None:
        return ""
    if "skipped" in r:
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped |"
                f" {r['skipped'][:40]}… |")
    rf = r["roofline"]
    peak = r["memory"]["peak_bytes"] / 2 ** 30
    ratio = r["useful_flops_ratio"]
    return (f"| {r['arch']} | {r['shape']} "
            f"| {rf['compute_s'] * 1e3:.2f} | {rf['memory_s'] * 1e3:.2f} "
            f"| {rf['collective_s'] * 1e3:.2f} | {peak:.2f} "
            f"| **{rf['bottleneck']}** | {ratio:.2f} |")


HILLCLIMBS = [
    # (arch, shape, variant label) — the three §Perf pairs
    ("glm4-9b", "train_4k", "fsdp"),
    ("llama4-scout-17b-a16e", "train_4k", "fsdp"),
    ("llama4-scout-17b-a16e", "train_4k", "fsdp_ep"),
    ("llava-next-mistral-7b", "decode_32k", "kvfp8"),
]


def compare():
    """§Perf before/after table from the recorded variant JSONs."""
    print("| arch | shape | variant | coll ms (base→opt) | "
          "peak GiB (base→opt) | memory ms (base→opt) |")
    print("|---|---|---|---|---|---|")
    for arch, shape, label in HILLCLIMBS:
        base = load(arch, shape, "singlepod")
        opt = load(arch, shape, "singlepod", label)
        if not base or not opt or "skipped" in base:
            continue
        bc = base["roofline"]["collective_s"] * 1e3
        oc = opt["roofline"]["collective_s"] * 1e3
        bp = base["memory"]["peak_bytes"] / 2 ** 30
        op = opt["memory"]["peak_bytes"] / 2 ** 30
        bm = base["roofline"]["memory_s"] * 1e3
        om = opt["roofline"]["memory_s"] * 1e3
        print(f"| {arch} | {shape} | {label} "
              f"| {bc:.0f} → {oc:.0f} ({oc/bc-1:+.0%}) "
              f"| {bp:.1f} → {op:.1f} ({op/bp-1:+.0%}) "
              f"| {bm:.2f} → {om:.2f} ({om/bm-1:+.0%}) |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="singlepod")
    ap.add_argument("--label", default="")
    ap.add_argument("--compare", action="store_true",
                    help="print the §Perf baseline-vs-optimized table")
    args = ap.parse_args()

    if args.compare:
        compare()
        return
    print("| arch | shape | compute ms | memory ms | collective ms "
          "| peak GiB/dev | bottleneck | MODEL/HLO FLOPs |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            row = fmt_row(load(arch, shape, args.mesh, args.label))
            if row:
                print(row)


if __name__ == "__main__":
    main()

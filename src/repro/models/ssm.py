"""Mamba2 / SSD (state-space duality) layer — chunked matmul form.

The chunked algorithm (arXiv:2405.21060 §6) is MXU-friendly: intra-chunk
work is batched matmuls, inter-chunk work is a short ``lax.scan`` over
chunk states. The Pallas kernel in ``repro.kernels.ssd_scan`` implements
the same algorithm with explicit VMEM tiling; this module is the pure-jnp
path (and the kernel's oracle lives in ``kernels/ssd_scan/ref.py``, which
delegates to :func:`ssd_chunked`).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.models.partitioning import shard


class SSMCache(NamedTuple):
    """Stacked per-repeat recurrent state.

    state: (repeats, batch, heads, head_dim, state_dim)  — SSD state
    conv:  (repeats, batch, conv_width-1, conv_dim)      — conv tail
    """

    state: jax.Array
    conv: jax.Array


def make_ssm_cache(cfg: ModelConfig, n_repeats: int, batch: int,
                   dtype=jnp.float32, abstract: bool = False):
    ssm = cfg.ssm
    inner = ssm.inner_dim(cfg.d_model)
    nh = ssm.n_heads(cfg.d_model)
    conv_dim = inner + 2 * ssm.state_dim
    sshape = (n_repeats, batch, nh, ssm.head_dim, ssm.state_dim)
    cshape = (n_repeats, batch, ssm.conv_width - 1, conv_dim)
    if abstract:
        return SSMCache(jax.ShapeDtypeStruct(sshape, jnp.float32),
                        jax.ShapeDtypeStruct(cshape, dtype))
    return SSMCache(jnp.zeros(sshape, jnp.float32), jnp.zeros(cshape, dtype))


def _segsum(log_a):
    """(..., L) -> (..., L, L) lower-triangular cumulative log-decays."""
    L = log_a.shape[-1]
    cum = jnp.cumsum(log_a, axis=-1)
    # decay from j (exclusive) to i (inclusive): cum_i - cum_j
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, d_skip, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x:  (B, S, H, P)   inputs per head
    dt: (B, S, H)      positive step sizes (already softplus'ed)
    a:  (H,)           negative decay rates (A = -exp(a_log))
    b:  (B, S, N)      input projection (single group shared over heads)
    c:  (B, S, N)      output projection
    d_skip: (H,)       skip connection
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, f"seq {S} not divisible by chunk {chunk}"

    f32 = jnp.float32
    xc = x.reshape(B, nc, chunk, H, P).astype(f32)
    dtc = dt.reshape(B, nc, chunk, H).astype(f32)
    bc = b.reshape(B, nc, chunk, N).astype(f32)
    cc = c.reshape(B, nc, chunk, N).astype(f32)

    log_dA = dtc * a  # (B,nc,L,H)  a<0
    log_dA_t = jnp.moveaxis(log_dA, -1, -2)          # (B,nc,H,L)
    seg = _segsum(log_dA_t)                          # (B,nc,H,L,L)
    decay = jnp.exp(seg)

    # diagonal (intra-chunk) term
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)       # (B,nc,L,L)
    scores = cb[:, :, None] * decay                  # (B,nc,H,L,L)
    xdt = xc * dtc[..., None]                        # (B,nc,L,H,P)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", scores, xdt)

    # chunk states: decay from j to end of chunk
    cum = jnp.cumsum(log_dA_t, axis=-1)              # (B,nc,H,L)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)      # (B,nc,H,L)
    states = jnp.einsum("bchj,bcjn,bcjhp->bchpn",
                        decay_to_end, bc, xdt)       # (B,nc,H,P,N)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[..., -1])              # (B,nc,H)
    s0 = (jnp.zeros((B, H, P, N), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        st, cd = inp                                  # (B,H,P,N), (B,H)
        new = carry * cd[..., None, None] + st
        return new, carry                             # emit state BEFORE chunk

    xs = (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    final, prev_states = jax.lax.scan(step, s0, xs)
    prev_states = jnp.moveaxis(prev_states, 0, 1)     # (B,nc,H,P,N)

    # off-diagonal (inter-chunk) term: y_off[i] = C_i . (decay_in * prev)
    decay_in = jnp.exp(cum)                           # (B,nc,H,L)
    y_off = jnp.einsum("bcin,bchpn,bchi->bcihp", cc, prev_states, decay_in)

    y = (y_diag + y_off).reshape(B, S, H, P)
    y = y + x.astype(f32) * d_skip[None, None, :, None]
    return y.astype(x.dtype), final


def ssd_decode_step(x, dt, a, b, c, d_skip, state):
    """Single-token SSD state update.

    x: (B,H,P), dt: (B,H), b,c: (B,N), state: (B,H,P,N) f32.
    """
    f32 = jnp.float32
    x32, dt32 = x.astype(f32), dt.astype(f32)
    dA = jnp.exp(dt32 * a)                            # (B,H)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt32, b.astype(f32), x32)
    new_state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", new_state, c.astype(f32))
    y = y + x32 * d_skip[None, :, None]
    return y.astype(x.dtype), new_state


def _causal_conv(xbc, w, bias, tail=None):
    """Depthwise causal conv, width W. xbc: (B,S,D), w: (W,D), tail: (B,W-1,D)."""
    W = w.shape[0]
    if tail is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = tail.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)        # (B, S+W-1, D)
    out = sum(full[:, i:i + xbc.shape[1]] * w[i] for i in range(W))
    new_tail = full[:, -(W - 1):] if W > 1 else None
    return out + bias, new_tail


def ssm_block(p, x, cfg: ModelConfig, *, cache=None, positions=None):
    """One Mamba2 block with residual.

    cache: per-repeat (state (B,H,P,N), conv_tail (B,W-1,D)) or None.
    positions: (B,S) with -1 for padding — padded steps get dt=0 so they
      leave the recurrent state untouched.
    x: (B,S,d). Returns (out, new_cache_or_None).
    """
    ssm = cfg.ssm
    d = cfg.d_model
    inner = ssm.inner_dim(d)
    nh = ssm.n_heads(d)
    N = ssm.state_dim

    h = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = h @ p["w_in"]                            # (B,S, 2*inner+2N+nh)
    zxbcdt = shard(zxbcdt, "batch", None, "act_inner")
    z = zxbcdt[..., :inner]
    xbc = zxbcdt[..., inner: 2 * inner + 2 * N]
    dt = zxbcdt[..., 2 * inner + 2 * N:]              # (B,S,nh)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    if positions is not None:
        dt = dt * (positions >= 0).astype(jnp.float32)[..., None]

    a = -jnp.exp(p["a_log"].astype(jnp.float32))      # (nh,)
    dsk = p["d_skip"].astype(jnp.float32)

    decode = cache is not None and x.shape[1] == 1
    tail = cache[1] if cache is not None else None
    xbc, new_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], tail)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :inner].reshape(x.shape[0], x.shape[1], nh, ssm.head_dim)
    bm = xbc[..., inner: inner + N]
    cm = xbc[..., inner + N:]

    new_cache = None
    if decode:
        y, new_state = ssd_decode_step(
            xs[:, 0], dt[:, 0], a, bm[:, 0], cm[:, 0], dsk, cache[0])
        y = y[:, None]
        new_cache = (new_state, new_tail)
    else:
        from repro.kernels import ops as K  # local import: no cycle at load
        init = cache[0] if cache is not None else None
        chunk = min(ssm.chunk_size, x.shape[1])
        if x.shape[1] % chunk:
            chunk = x.shape[1]  # fall back to one chunk for odd small seqs
        y, final = K.ssd(xs, dt, a, bm, cm, dsk, chunk, init)
        if cache is not None:
            new_cache = (final, new_tail)

    y = y.reshape(x.shape[0], x.shape[1], inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["out_norm"], cfg.norm_eps)
    out = y @ p["w_out"]
    return x + shard(out, "batch", None, "act_embed"), new_cache

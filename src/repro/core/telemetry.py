"""Stage-level telemetry: span tracer, unified metrics registry, and
per-request latency attribution.

The paper's headline claims are SLO claims (TTFT < 2 s, TPOT < 50 ms
under E/P/D disaggregation), so the serving stack needs to answer not
just *whether* a request met its deadline but *where its time went*.
This module is the single observability plane shared by the real
``Engine``/``EPDCluster`` (wall time) and the ``Simulator`` (simulated
time), three layers deep:

* :class:`Tracer` — an allocation-light span recorder.
  ``tracer.span(name, request_id=..., **attrs)`` is a context manager
  around a pipeline phase (a prefill chunk, a decode step, a swap-out);
  ``tracer.add(...)`` records spans with *modeled* timestamps (transfer
  groups, retry backoffs — things that never run on this host's clock).
  A disabled tracer (the default) returns a shared no-op context
  manager: zero allocations, zero recorded spans, zero behavior change.
  Spans carry a ``track`` (one per engine instance / link) so the
  Chrome-trace exporter (``core.trace_export``) renders one timeline
  row per instance.

* :class:`MetricsRegistry` — labeled counters / gauges / histograms.
  The ad-hoc counters that used to live on ``Engine`` (refault pages,
  swap totals), ``ClusterReport`` (retry counts, retry time) and
  ``PagePool`` (peak occupancy) now live here under stable names; the
  old attribute names survive as read-through properties. One registry
  per cluster/simulator run; ``snapshot()`` is JSON-able and lands in
  every ``BENCH_*.json`` under the ``"telemetry"`` key.

* :class:`LatencyAccountant` — per-request latency attribution. Every
  request's end-to-end latency is decomposed into the five
  :data:`COMPONENTS` (queue / compute / transfer / swap / retry) on a
  single accounting clock, with the structural invariant that the
  components sum to the end-to-end measurement: every clock advance —
  a wall-time segment (``sync``) or a modeled charge (``advance``) —
  is charged to *every* open request under its current state, so no
  interval of a request's lifetime is ever unattributed.
  ``mark_first_token`` snapshots the components at the TTFT gate,
  giving separate TTFT and TPOT decompositions.

:func:`quantile` is the one histogram-quantile implementation (linear
interpolation, correct at n == 0 and n == 1) reused by ``SimMetrics``
and the benchmark suite.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

# The five latency components every request's end-to-end time is
# attributed to. "queue" is any time spent waiting for a resource
# (ingress queue, decode admission, parked-preempted); "compute" is
# encode/prefill/decode service time; "transfer" is exposed P->D KV
# movement (the part not hidden under compute); "swap" is preemption
# swap-out/in + re-fault work; "retry" is fault-recovery backoff and
# wasted attempts charged by the chaos layer.
COMPONENTS = ("queue", "compute", "transfer", "swap", "retry")


# ---------------------------------------------------------------------------
# Quantiles
# ---------------------------------------------------------------------------

def quantile(xs, p: float) -> float:
    """Linear-interpolation quantile of ``xs`` (need not be sorted).

    Correct at the edges the old ad-hoc helpers got wrong: an empty
    input returns 0.0 (not an IndexError), a single sample returns that
    sample for every ``p``, and ``p`` outside [0, 1] clamps. This is
    the single implementation behind ``Histogram.quantile``,
    ``SimMetrics`` p99s, and the benchmark reports.
    """
    xs = sorted(xs)
    n = len(xs)
    if n == 0:
        return 0.0
    if n == 1:
        return float(xs[0])
    p = min(1.0, max(0.0, float(p)))
    pos = p * (n - 1)
    lo = int(pos)
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return float(xs[lo]) * (1.0 - frac) + float(xs[hi]) * frac


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

class Counter:
    """Monotonic labeled counter (floats allowed: retry *time* is a
    counter too — it only ever accumulates)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease by {v}")
        self.value += v


class Gauge:
    """Last-written-value gauge (pool occupancy, hit rates)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def max(self, v: float) -> None:
        """High-water-mark update (peak pool occupancy)."""
        if v > self.value:
            self.value = float(v)


class Histogram:
    """Exact-sample histogram: stores observations, answers quantiles
    via :func:`quantile`. Fine at serving-benchmark cardinalities; a
    production system would swap in fixed buckets behind the same API."""

    __slots__ = ("name", "labels", "values")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def sum(self) -> float:
        return float(sum(self.values))

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.values else 0.0

    def quantile(self, p: float) -> float:
        return quantile(self.values, p)


def _label_key(labels: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _fmt_key(name: str, labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Get-or-create registry of labeled metrics.

    ``registry.counter("kv_transfer_retries", site="transfer.wire")``
    returns the same Counter object on every call with the same name
    and label set, so hot paths can cache the handle and ``inc()`` it
    without a lookup. A name must keep one metric type across all its
    label sets.
    """

    def __init__(self):
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], Any] = {}
        self._types: Dict[str, type] = {}

    def _get(self, cls, name: str, labels: Dict[str, Any]):
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            want = self._types.setdefault(name, cls)
            if want is not cls:
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{want.__name__}, requested {cls.__name__}")
            m = self._metrics[key] = cls(name, key[1])
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def value(self, name: str, **labels) -> float:
        """Current value of a counter/gauge (0.0 when never touched)."""
        m = self._metrics.get((name, _label_key(labels)))
        return m.value if m is not None else 0.0

    def total(self, name: str) -> float:
        """Sum of a counter/gauge across all its label sets."""
        return sum(m.value for (n, _), m in self._metrics.items()
                   if n == name and not isinstance(m, Histogram))

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able dump: every metric keyed ``name{k=v,...}``. This is
        what benchmarks embed under the ``"telemetry"`` key so bench
        deltas can diff component-level counters, not just wall clocks."""
        out: Dict[str, Any] = {"counters": {}, "gauges": {}, "histograms": {}}
        for (name, labels), m in sorted(self._metrics.items()):
            key = _fmt_key(name, labels)
            if isinstance(m, Counter):
                out["counters"][key] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][key] = m.value
            else:
                out["histograms"][key] = {
                    "count": m.count, "sum": m.sum, "mean": m.mean,
                    "p50": m.quantile(0.50), "p99": m.quantile(0.99),
                    "max": max(m.values) if m.values else 0.0,
                }
        return out


# ---------------------------------------------------------------------------
# Span tracer
# ---------------------------------------------------------------------------

@dataclass
class Span:
    """One closed interval on one track. ``start``/``end`` are seconds
    on the tracer's clock (wall, accounting, or simulated — the track's
    spans share a timebase, which is all the exporter needs)."""

    name: str
    track: str
    start: float
    end: float
    request_id: Optional[int] = None
    parent: Optional[str] = None
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.end - self.start


class _NullSpan:
    """Shared no-op context manager: the disabled tracer's entire cost."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _SpanCM:
    __slots__ = ("_tracer", "_name", "_track", "_rid", "_attrs", "_start",
                 "_parent")

    def __init__(self, tracer: "Tracer", name: str, track: str,
                 rid: Optional[int], attrs: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._track = track
        self._rid = rid
        self._attrs = attrs

    def __enter__(self):
        t = self._tracer
        self._parent = t._stack[-1] if t._stack else None
        t._stack.append(self._name)
        self._start = t.now()
        return self

    def __exit__(self, *exc):
        t = self._tracer
        t._stack.pop()
        t.spans.append(Span(self._name, self._track, self._start, t.now(),
                            self._rid, self._parent, self._attrs))
        return False


class Tracer:
    """Span recorder. ``enabled=False`` (the default everywhere) makes
    ``span()`` return a shared no-op context manager — no allocation,
    no clock read — so tracing can stay compiled into every hot path.

    ``now`` is the clock: wall time by default, the cluster's accounting
    clock or the simulator's event-loop time when those own the run
    (``set_clock``). ``decode_sample`` thins the highest-frequency span
    family: engines record one batched ``decode_step`` span every N
    steps instead of every step.
    """

    def __init__(self, enabled: bool = False,
                 now: Optional[Callable[[], float]] = None,
                 decode_sample: int = 1):
        if decode_sample < 1:
            raise ValueError(f"decode_sample must be >= 1, "
                             f"got {decode_sample}")
        self.enabled = enabled
        self.now = now if now is not None else time.perf_counter
        self.decode_sample = decode_sample
        self.spans: List[Span] = []
        self._stack: List[str] = []

    def set_clock(self, now: Callable[[], float]) -> None:
        self.now = now

    def span(self, name: str, track: str = "main",
             request_id: Optional[int] = None, **attrs):
        if not self.enabled:
            return NULL_SPAN
        return _SpanCM(self, name, track, request_id, attrs)

    def add(self, name: str, start: float, end: float, track: str = "main",
            request_id: Optional[int] = None, parent: Optional[str] = None,
            **attrs) -> None:
        """Record a span with explicit timestamps — modeled timelines
        (transfer-group schedules, retry backoffs, simulator service
        times) that never ran on this host's clock."""
        if not self.enabled:
            return
        if end < start:
            raise ValueError(f"span {name!r} ends before it starts "
                             f"({end} < {start})")
        self.spans.append(Span(name, track, start, end, request_id,
                               parent, attrs))

    def want_decode_span(self, step: int) -> bool:
        return self.enabled and step % self.decode_sample == 0

    # -- audits ---------------------------------------------------------------
    def assert_balanced(self) -> None:
        """Every opened span must have been closed (the ``with`` block
        exited) and every recorded span must be well-formed. The span
        analogue of the page pool's ``assert_balanced`` leak audit."""
        assert not self._stack, (
            f"unclosed spans: {self._stack} — a span context manager "
            f"was entered but never exited")
        for s in self.spans:
            assert s.end >= s.start, (
                f"span {s.name!r} on {s.track!r} ends before it starts")

    def tracks(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for s in self.spans:
            out[s.track] = out.get(s.track, 0) + 1
        return out


NULL_TRACER = Tracer(enabled=False)


# ---------------------------------------------------------------------------
# Latency attribution
# ---------------------------------------------------------------------------

@dataclass
class AttributionRecord:
    """One request's latency decomposition on the accounting clock."""

    request_id: int
    t_open: float
    components: Dict[str, float]
    t_first_token: float = -1.0
    ttft_components: Optional[Dict[str, float]] = None
    t_close: float = -1.0
    n_output_tokens: int = 0

    @property
    def closed(self) -> bool:
        return self.t_close >= 0

    @property
    def e2e(self) -> float:
        """End-to-end latency measured directly on the clock — the
        number the components must sum to."""
        return (self.t_close - self.t_open) if self.closed else -1.0

    @property
    def total(self) -> float:
        return sum(self.components.values())

    @property
    def ttft(self) -> float:
        return (self.t_first_token - self.t_open) \
            if self.t_first_token >= 0 else -1.0

    def decode_components(self) -> Dict[str, float]:
        """Post-first-token share of each component (the TPOT side)."""
        base = self.ttft_components or {c: 0.0 for c in COMPONENTS}
        return {c: self.components[c] - base.get(c, 0.0)
                for c in COMPONENTS}

    def tpot_components_ms(self) -> Dict[str, float]:
        """Per-output-token decode decomposition in milliseconds."""
        n = max(1, self.n_output_tokens - 1)
        return {c: v * 1e3 / n for c, v in self.decode_components().items()}

    def check(self, tol: float = 0.01) -> None:
        """The attribution invariant: components sum to the end-to-end
        measurement within ``tol`` (relative). A failure means some code
        path advanced the clock without charging an open request —
        i.e. unattributed latency."""
        assert self.closed, f"request {self.request_id} never closed"
        gap = abs(self.total - self.e2e)
        assert gap <= tol * max(self.e2e, 1e-9) + 1e-12, (
            f"request {self.request_id}: components sum {self.total:.6f}s "
            f"!= e2e {self.e2e:.6f}s (gap {gap:.6f}s)")

    def as_dict(self) -> Dict[str, Any]:
        return {
            "request_id": self.request_id,
            "e2e_ms": round(self.e2e * 1e3, 4),
            "ttft_ms": round(self.ttft * 1e3, 4),
            "components_ms": {c: round(v * 1e3, 4)
                              for c, v in self.components.items()},
            "ttft_components_ms": (
                {c: round(v * 1e3, 4)
                 for c, v in (self.ttft_components or {}).items()}),
        }


class LatencyAccountant:
    """Exhaustive per-request latency ledger on one accounting clock.

    Two clock sources compose into ``now``:

    * ``sync()`` — reads the wall clock and charges the elapsed segment
      (the real cluster calls it at every state transition and after
      every engine step);
    * ``advance(dt, ...)`` — charges *modeled* time (transfer exposure,
      retry backoff, simulated service times). The simulator drives the
      whole accountant this way via ``EventLoop.on_advance``.

    Every charge goes to **all** open requests, each under its current
    state — except that ``advance`` may override one request's
    component (the request the modeled time belongs to, e.g. ``retry``
    for a backoff that everyone else experiences as queueing). That is
    what makes the sum-of-components == e2e invariant structural: no
    clock movement is ever unattributed. ``note`` moves already-charged
    time between a request's components (zero-sum, clamped) for
    after-the-fact reclassification — e.g. the slice of a parked
    request's wait that was really swap traffic.
    """

    def __init__(self, wall: Optional[Callable[[], float]] = None):
        self._wall = wall
        self._last = wall() if wall is not None else 0.0
        self.now = 0.0
        self.records: Dict[int, AttributionRecord] = {}
        self._open: Dict[int, str] = {}
        self._alias: Dict[int, int] = {}

    # -- clock ----------------------------------------------------------------
    def clock(self) -> float:
        """Continuous view of the accounting clock: ``now`` plus the
        wall time elapsed since the last ``sync()`` (as if a sync
        happened this instant). Bind this as the tracer clock so spans
        recorded between syncs land on the same timebase as modeled
        transfer/retry spans. Monotone: ``sync`` folds the elapsed
        segment into ``now`` and resets the reference point."""
        if self._wall is None:
            return self.now
        return self.now + max(0.0, self._wall() - self._last)

    def sync(self) -> None:
        if self._wall is None:
            return
        t = self._wall()
        dt = t - self._last
        self._last = t
        if dt > 0:
            self._charge(dt)

    def _charge(self, dt: float,
                override: Optional[Dict[int, str]] = None) -> None:
        self.now += dt
        for rid, state in self._open.items():
            comp = state
            if override is not None:
                comp = override.get(rid, state)
            self.records[rid].components[comp] += dt

    def advance(self, dt: float, request_id: Optional[int] = None,
                component: Optional[str] = None) -> None:
        """Charge ``dt`` of modeled time: to ``request_id`` under
        ``component`` (when given and open), to every other open
        request under its current state."""
        if dt <= 0:
            return
        override = None
        if request_id is not None and component is not None:
            rid = self._alias.get(request_id, request_id)
            if rid in self._open:
                if component not in COMPONENTS:
                    raise ValueError(f"unknown component {component!r}")
                override = {rid: component}
        self._charge(dt, override)

    # -- request lifecycle ----------------------------------------------------
    def open(self, request_id: int, state: str = "queue") -> None:
        self.sync()
        if request_id in self.records:
            return                      # requeue of a known request
        if state not in COMPONENTS:
            raise ValueError(f"unknown component {state!r}")
        self.records[request_id] = AttributionRecord(
            request_id=request_id, t_open=self.now,
            components={c: 0.0 for c in COMPONENTS})
        self._open[request_id] = state

    def alias(self, alt_id: int, request_id: int) -> None:
        """Attribute charges against ``alt_id`` to ``request_id`` — a
        crash re-route's shadow prefill bills the original request."""
        self._alias[alt_id] = request_id

    def state(self, request_id: int) -> Optional[str]:
        return self._open.get(self._alias.get(request_id, request_id))

    def set_state(self, request_id: int, state: str) -> None:
        rid = self._alias.get(request_id, request_id)
        if rid not in self._open:
            return
        if state not in COMPONENTS:
            raise ValueError(f"unknown component {state!r}")
        self.sync()
        self._open[rid] = state

    def note(self, request_id: int, component: str, amount: float,
             source: str) -> float:
        """Zero-sum reclassification: move up to ``amount`` seconds of
        ``request_id``'s already-charged ``source`` component into
        ``component``. Returns the amount actually moved (clamped to
        the source balance, so the invariant cannot break)."""
        rid = self._alias.get(request_id, request_id)
        rec = self.records.get(rid)
        if rec is None or amount <= 0:
            return 0.0
        if component not in COMPONENTS or source not in COMPONENTS:
            raise ValueError(f"unknown component {component!r}/{source!r}")
        moved = min(float(amount), rec.components[source])
        rec.components[source] -= moved
        rec.components[component] += moved
        return moved

    def mark_first_token(self, request_id: int,
                         n_output_tokens: int = 1) -> None:
        rid = self._alias.get(request_id, request_id)
        rec = self.records.get(rid)
        if rec is None or rec.t_first_token >= 0:
            return
        self.sync()
        rec.t_first_token = self.now
        rec.ttft_components = dict(rec.components)
        rec.n_output_tokens = n_output_tokens

    def close(self, request_id: int, n_output_tokens: int = 0) -> None:
        rid = self._alias.get(request_id, request_id)
        if rid not in self._open:
            return
        self.sync()
        del self._open[rid]
        rec = self.records[rid]
        rec.t_close = self.now
        if n_output_tokens:
            rec.n_output_tokens = n_output_tokens

    # -- reports --------------------------------------------------------------
    @property
    def n_open(self) -> int:
        return len(self._open)

    def assert_all_closed(self) -> None:
        assert not self._open, (
            f"requests still open in the latency ledger: "
            f"{sorted(self._open)}")

    def component_total(self, component: str) -> float:
        if component not in COMPONENTS:
            raise ValueError(f"unknown component {component!r}")
        return sum(r.components[component] for r in self.records.values())

    def check_all(self, tol: float = 0.01) -> None:
        for rec in self.records.values():
            if rec.closed:
                rec.check(tol)

    def report(self) -> Dict[str, Any]:
        """Aggregate attribution report: per-request rows plus mean
        component decomposition (JSON-able — benchmarks embed it)."""
        closed = [r for r in self.records.values() if r.closed]
        mean = {c: 0.0 for c in COMPONENTS}
        for r in closed:
            for c in COMPONENTS:
                mean[c] += r.components[c]
        n = max(1, len(closed))
        return {
            "n_requests": len(closed),
            "mean_components_ms": {c: round(v * 1e3 / n, 4)
                                   for c, v in mean.items()},
            "mean_e2e_ms": round(
                sum(r.e2e for r in closed) * 1e3 / n, 4),
            "requests": [r.as_dict() for r in
                         sorted(closed, key=lambda r: r.request_id)],
        }


def snapshot_json(registry: MetricsRegistry) -> str:
    """Round-trippable snapshot string (CI artifacts, debugging)."""
    return json.dumps(registry.snapshot(), indent=2, sort_keys=True)

"""End-to-end behaviour of the EPD-Serve system (real compute + simulator).

The headline checks: a multimodal request stream served through the
disaggregated E->P->D pipeline produces exactly the monolithic engine's
tokens, and the simulator reproduces the paper's headline effect —
EPD disaggregation with co-location beats PD-style deployments on
effective throughput under SLO.
"""
import jax
import pytest

from repro.configs import get_config
from repro.core.cluster import EPDCluster
from repro.core.simulator import SHAREGPT_4O, simulate
from repro.models.model import init_params
from repro.serving.engine import Engine
from repro.serving.request import Request


@pytest.mark.parametrize("mode", ["dense", "paged", "chunked"])
def test_disaggregation_is_transparent_to_outputs(mode):
    """Tokens must not depend on the serving topology — dense, paged, or
    chunked+prefix-cached prefill all reproduce the monolithic engine."""
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))

    prompts = [[3, 1, 4, 1, 5], [9, 2, 6, 5], [3, 5, 8, 9, 7, 9]]
    mono_out, epd_out = [], []

    mono = Engine(cfg, params, max_batch=4, max_len=64)
    for p in prompts:
        r = Request(prompt_tokens=list(p), max_new_tokens=6)
        mono.run_request(r)
        mono_out.append(r.output_tokens)

    kw = {}
    if mode != "dense":
        kw = dict(paged=True, page_size=8)
    if mode == "chunked":
        kw.update(chunked_prefill=True, prefill_chunk=8, prefix_cache=True,
                  n_prefill_pool_pages=33)
    cluster = EPDCluster(cfg, params, max_batch=4, max_len=64, **kw)
    reqs = [Request(prompt_tokens=list(p), max_new_tokens=6) for p in prompts]
    for r in reqs:
        cluster.submit(r)
    cluster.run_until_done()
    epd_out = [r.output_tokens for r in reqs]

    assert mono_out == epd_out
    if mode != "dense":
        # page-refcount audit: nothing may outlive the drained requests
        # but the prefix tree's retentions
        cluster.prefill_engine.assert_no_page_leaks()
        cluster.decode_engine.assert_no_page_leaks()
        assert cluster.decode_engine.pool.n_used == 0


def test_paper_headline_epd_beats_pd_on_effective_throughput():
    """Paper abstract: EPD disaggregation improves effective throughput
    over PD-disaggregated deployment under TTFT<=2000ms / TPOT<=50ms.

    PD-disaggregation (no separate Encode) == 'EP-D' here: encode rides
    with prefill. The paper's (E-P)-D improves on it by 57-69%; we assert
    a substantial (>20%) win, hardware constants differ."""
    model = get_config("openpangu-7b-vl")
    pd = simulate(model, "EP-D", SHAREGPT_4O, rate=8.0, n_requests=256,
                  seed=11)
    epd = simulate(model, "(E-P)-D", SHAREGPT_4O, rate=8.0, n_requests=256,
                   seed=11)
    eff_pd = pd.effective_throughput(2000, 50)
    eff_epd = epd.effective_throughput(2000, 50)
    assert eff_epd > eff_pd * 1.2, (eff_pd, eff_epd)


def test_slo_degrades_gracefully_with_rate():
    model = get_config("openpangu-7b-vl")
    slos = []
    for rate in (2.0, 6.0, 10.0):
        m = simulate(model, "(E-P)-D", SHAREGPT_4O, rate=rate,
                     n_requests=128, seed=2)
        slos.append(m.slo_attainment(2000, 50))
    assert slos[0] >= slos[1] >= slos[2] - 1e-9
    assert slos[0] > 0.9

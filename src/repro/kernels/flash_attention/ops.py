"""Jit'd public wrapper for the flash-attention kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import dispatch
from repro.kernels.flash_attention.kernel import flash_attention as _kernel
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("window", "causal", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, q_pos, kv_pos, *, window: Optional[int] = None,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 512, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = dispatch.interpret()
    return _kernel(q, k, v, q_pos, kv_pos, window=window, causal=causal,
                   block_q=block_q, block_k=block_k, interpret=interpret)


__all__ = ["flash_attention", "attention_ref"]

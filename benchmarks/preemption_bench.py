"""Preemption overload benchmark: swap-and-resume vs kill-on-OOM.

Overload sweep on the EPD simulator: a burst of text requests whose
steady-state KV demand exceeds the Decode pool (sized for ~60% of the
offered load). The kill baseline drops requests when decode growth
overflows the pool; preemption swaps victims to host (charged at the
CostModel host-link rate) and resumes them when pages free up.

Reports completed requests, kills, preemptions, and p99 TPOT for both
modes at each pool size, plus a REAL-engine spot check (preempt/resume
greedy parity + zero leaked pages / dangling swap handles). Emits a
BENCH_preemption.json snapshot next to the repo root so the perf
trajectory is recorded per PR.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List


def bench_preemption() -> List[str]:
    from repro.configs import get_config
    from repro.core.simulator import SHAREGPT_4O, simulate

    model = get_config("openpangu-7b-vl")
    n_requests, rate, out_toks = 48, 32.0, 96
    ds = dataclasses.replace(SHAREGPT_4O, mm_fraction=0.0,
                             text_tokens_mean=256.0,
                             output_tokens=out_toks)
    # peak demand: every request concurrently holding prompt+output KV
    peak_pages = n_requests * ((256 + out_toks) // 16 + 1)
    rows = ["preemption,value,derived"]
    snap = {"config": {"model": "openpangu-7b-vl", "dataset": "text-256",
                       "n_requests": n_requests, "rate": rate,
                       "output_tokens": out_toks, "page_tokens": 16,
                       "peak_demand_pages": peak_pages},
            "sweep": []}

    for frac in (0.5, 0.6, 0.75):
        cap = int(peak_pages * frac)
        kw = dict(rate=rate, n_requests=n_requests, seed=3,
                  kv_page_tokens=16, decode_kv_pages=cap)
        kill = simulate(model, "E-P-D", ds, **kw)
        pre = simulate(model, "E-P-D", ds, preemption=True, **kw)
        assert pre.killed_requests == 0, "preemption must never kill"
        assert pre.completed_requests == n_requests, \
            "preemption must complete every request"
        if kill.killed_requests:
            assert pre.completed_requests > kill.completed_requests, \
                f"preemption must beat the kill baseline at cap {cap}"
        snap["sweep"].append({
            "pool_fraction": frac, "decode_kv_pages": cap,
            "kill_completed": kill.completed_requests,
            "kill_killed": kill.killed_requests,
            "kill_p99_tpot_ms": round(kill.p99_tpot_ms, 2),
            "preempt_completed": pre.completed_requests,
            "preempt_preemptions": pre.n_preemptions,
            "preempt_p99_tpot_ms": round(pre.p99_tpot_ms, 2),
        })
        rows.append(
            f"overload_{int(frac * 100)}pct,"
            f"{kill.completed_requests}->{pre.completed_requests}"
            f"_completed,kills_{kill.killed_requests}->0_"
            f"preempts_{pre.n_preemptions}_p99tpot_"
            f"{kill.p99_tpot_ms:.0f}->{pre.p99_tpot_ms:.0f}ms")
    # metrics-registry snapshot of the last preemption run: preemption
    # counters + mean per-request latency attribution
    snap["telemetry"] = pre.telemetry
    snap["mean_components_ms"] = pre.attribution["mean_components_ms"]

    # REAL-engine spot check: forced preempt/resume keeps greedy parity
    # and the audit finds no leaked pages or dangling swap handles
    import jax
    from repro.models.model import init_params
    from repro.serving.engine import Engine
    from repro.serving.request import Request

    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))

    def serve(eng, preempt_at=()):
        r = Request(prompt_tokens=list(range(2, 15)), max_new_tokens=8)
        f, p = eng.prefill_request(r)
        eng.insert(r, p, f)
        step = 0
        while (any(s is r for s in eng.slots)
               or any(pr.req is r for pr in eng.preempted)):
            if step in preempt_at and any(s is r for s in eng.slots):
                eng.preempt_slot(next(i for i, s in enumerate(eng.slots)
                                      if s is r))
            eng.decode_step()
            step += 1
        return r.output_tokens

    base = Engine(cfg, params, max_batch=2, max_len=64, paged=True,
                  page_size=8)
    eng = Engine(cfg, params, max_batch=2, max_len=64, paged=True,
                 page_size=8, preemption=True)
    want = serve(base)
    got = serve(eng, preempt_at=(1, 3, 5))
    assert got == want, "preempt/resume broke greedy parity"
    eng.assert_no_page_leaks()
    assert eng.pool.n_used == 0 and eng.pool.n_swapped_pages == 0
    snap["engine_parity"] = {"preempts": eng.preempt_count,
                             "swapped_pages": eng.swap_out_pages_total,
                             "leaked_pages": 0, "dangling_handles": 0}
    rows.append(f"engine_parity,ok,{eng.preempt_count}_preempts_"
                f"{eng.swap_out_pages_total}_pages_swapped_0_leaks")

    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_preemption.json")
    with open(os.path.abspath(out), "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows


if __name__ == "__main__":
    for row in bench_preemption():
        print(row)

"""The real Encode-stage instance (paper §3.2, the E in EPD).

One ``EncodeEngine`` is one Encode serving instance: it runs the modality
frontend forward — the stubbed ViT/conv trunk plus the REAL learned
projector (``params['projector']``), jitted via ``steps.make_encode_fn``
— and lands the resulting d_model-wide feature tensor in the shared
``MMStore`` under the input's content hash. Downstream, a Prefill
engine consumes the features by scattering them into the embedding
stream at image-token positions (``prefill_request(mm_feats=...)``),
and the ``EPPrefetcher`` hides the E->P hand-off under scheduling.

Dedup is the stage's cheapest win: a payload whose hash is already
resident skips the forward entirely (cross-request reuse — the store's
hit/miss stats track exactly this). ``compute_features`` is also the
cluster's fault-tolerant recompute arm: a Prefill-side store miss calls
it to rebuild the feature tensor locally, and because the same jitted
projector forward runs in both places the recompute is bit-identical
to the original encode.

For encoder-decoder archs (whisper-class), the cross-attention encoder
runs inside prefill against raw frames, so the store payload is the raw
stub frame tensor, un-projected.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.mm_store import MMStore
from repro.core.telemetry import NULL_TRACER, MetricsRegistry, Tracer
from repro.models import frontend as FE
from repro.serving.request import Request
from repro.serving.steps import make_encode_fn


class EncodeEngine:
    def __init__(self, cfg: ModelConfig, params, *, store: MMStore,
                 name: str = "E0",
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        if cfg.frontend is None:
            raise ValueError(f"{cfg.name} has no modality frontend — "
                             f"an Encode instance has nothing to run")
        self.cfg = cfg
        self.params = params
        self.store = store
        self.name = name
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # VLM-class: jitted trunk+projector forward. Whisper-class keeps
        # raw frames (the encoder runs under prefill's cross-attention).
        self._encode = make_encode_fn(cfg) if cfg.encoder is None else None
        M = self.metrics
        self._m_requests = M.counter("encode_requests_total", engine=name)
        self._m_tokens = M.counter("encode_tokens_total", engine=name)
        self._m_dedup = M.counter("encode_dedup_total", engine=name)

    def compute_features(self, payload: bytes,
                         n_tokens: int = 0) -> np.ndarray:
        """Run the frontend forward for one payload: stub trunk ->
        learned projector -> (n_tokens, d_model) float32. This is the
        single implementation behind the Encode stage AND the
        Prefill-side store-miss recompute arm, so recomputes are
        bit-identical to the features they replace."""
        patches = FE.stub_embeddings(self.cfg, payload, n_tokens or None)
        if self._encode is None:
            return np.asarray(patches)          # raw frames (whisper)
        return np.asarray(self._encode(self.params, patches))

    def encode_request(self, req: Request) -> str:
        """Encode one request's payload into the MM Store; returns the
        content-hash key the Prefill stage will fetch by. A resident key
        skips the forward (dedup — the §3.2 cross-request reuse path);
        ``contains`` doesn't consume injected store faults, so those hit
        the Prefill-side fetch and exercise the recompute arm."""
        key = FE.content_hash(req.mm_payload)
        self._m_requests.inc()
        with self.tracer.span("encode.forward", track=self.name,
                              request_id=req.request_id,
                              tokens=req.mm_tokens):
            if self.store.contains(key):
                self.store.stats.hits += 1
                self._m_dedup.inc()
            else:
                self.store.stats.misses += 1
                feats = self.compute_features(req.mm_payload, req.mm_tokens)
                self.store.put(key, feats, feats.nbytes)
                self._m_tokens.inc(feats.shape[0])
        return key

    def dispatch(self, req: Request) -> Tuple[str, bool]:
        """Iteration-loop entry point: encode ``req`` and report whether
        the forward actually ran (``ran=False`` = store dedup hit). The
        continuous scheduler uses ``ran`` to decide whether the E->P
        feature barrier charges encode time or the feature is free —
        dedup'd features carry no arrival dependency."""
        key = FE.content_hash(req.mm_payload)
        ran = not self.store.contains(key)
        self.encode_request(req)
        return key, ran

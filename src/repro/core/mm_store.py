"""MM Store — the shared multimodal feature cache pool (paper §3.2).

Content-hash keyed: key = hash(multimodal input), value = encoded feature
tensor (or, in simulation, its metadata). Supports cross-request reuse
(dedup), LRU eviction under a byte budget, and fault injection so the
fault-tolerant recomputation path is testable.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from repro.core.faults import SITE_STORE_FETCH, FaultInjector, StoreMiss


@dataclass
class StoreStats:
    puts: int = 0
    hits: int = 0
    misses: int = 0
    dedup_puts: int = 0          # put of an already-present key
    evictions: int = 0
    faults_injected: int = 0
    bytes_stored: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class MMStore:
    """Hash-keyed feature pool with LRU eviction."""

    def __init__(self, capacity_bytes: Optional[int] = None,
                 injector: Optional[FaultInjector] = None):
        self.capacity = capacity_bytes
        self._data: "collections.OrderedDict[str, Tuple[Any, int]]" = \
            collections.OrderedDict()
        self.stats = StoreStats()
        # All fault decisions route through the (possibly shared) fault
        # plane; a private injector with an empty plan means "no faults"
        # until someone arms one via inject_fault.
        self.injector = injector if injector is not None else FaultInjector()

    # -- core API -------------------------------------------------------------
    def put(self, key: str, value: Any, nbytes: int) -> None:
        if key in self._data:
            self.stats.dedup_puts += 1
            self._data.move_to_end(key)
            return
        self.stats.puts += 1
        self._data[key] = (value, nbytes)
        self.stats.bytes_stored += nbytes
        self._evict()

    def get(self, key: str, record: bool = True,
            attempt: int = 0) -> Optional[Any]:
        """record=False: internal fetch (e.g. the P-side prefetcher pulling
        a feature the E stage just produced) — served but not counted in
        the hit/miss statistics, which track cross-request dedup.
        ``attempt`` keys the injector's deterministic draw: a *retry* of
        the same fetch re-draws, so transient faults heal under the
        store-fetch retry arm."""
        if self.injector.should_fail(SITE_STORE_FETCH, key=key,
                                     attempt=attempt):
            # injected fault: behaves like a lost entry (paper §3.2 FT path)
            self.stats.faults_injected += 1
            if record:
                self.stats.misses += 1
            return None
        if key in self._data:
            if record:
                self.stats.hits += 1
            self._data.move_to_end(key)
            return self._data[key][0]
        if record:
            self.stats.misses += 1
        return None

    def contains(self, key: str) -> bool:
        return key in self._data

    def nbytes(self, key: str) -> int:
        return self._data[key][1] if key in self._data else 0

    def _evict(self) -> None:
        if self.capacity is None:
            return
        while self.stats.bytes_stored > self.capacity and len(self._data) > 1:
            _, (_, nb) = self._data.popitem(last=False)
            self.stats.bytes_stored -= nb
            self.stats.evictions += 1

    def fetch(self, key: str, attempt: int = 0) -> Any:
        """Typed fetch: like ``get`` but a lost/faulted/absent entry
        raises :class:`StoreMiss` (carrying the key and attempt number)
        instead of returning None — what the retry-then-recompute arm
        catches."""
        val = self.get(key, attempt=attempt)
        if val is None:
            raise StoreMiss(key, attempts=attempt + 1)
        return val

    # -- fault injection --------------------------------------------------------
    def inject_fault(self, key: str) -> None:
        """Legacy one-shot hook, kept as a shim: arms exactly one
        store-fetch fault for ``key`` on the shared injector."""
        self.injector.arm(SITE_STORE_FETCH, key=key)

    def __len__(self) -> int:
        return len(self._data)

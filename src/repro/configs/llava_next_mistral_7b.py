"""llava-next-mistral-7b [vlm] — mistral-7b backbone, anyres ViT STUB.

[hf:llava-hf/llava-v1.6-mistral-7b-hf] — anyres tiling: base image +
up to 4 tiles, 576 patch embeddings each (24x24 @ CLIP ViT-L/14-336).
The ViT trunk is a stub; the projector (2-layer MLP in the original,
linear here) and the full language backbone are implemented.
"""
from repro.configs.base import FrontendConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    pattern=(LayerSpec("attn", "mlp"),),
    frontend=FrontendConfig(kind="vision", tokens_per_item=2880,  # 5 x 576
                            feature_dim=1024),
    rope_theta=1_000_000.0,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

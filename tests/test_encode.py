"""Encode stage on the serving path: MM Store correctness fixes
(dedup-put reconciliation, oversized-entry eviction, pin/unpin),
EPPrefetcher announce/fire race handling, the EncodeEngine itself, and
the cluster-level E->P overlap arms (async / sync / inline) — which must
be bit-identical in output and differ only in modeled accounting."""
import random

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cluster import EPDCluster
from repro.core.costmodel import CostModel
from repro.core.ep_prefetch import EPPrefetcher
from repro.core.events import EventLoop
from repro.core.mm_store import MMStore
from repro.core.telemetry import Tracer
from repro.models import frontend as FE
from repro.models.model import init_params
from repro.serving.encode_engine import EncodeEngine
from repro.serving.engine import Engine
from repro.serving.request import Request


@pytest.fixture(scope="module")
def llava():
    cfg = get_config("llava-next-mistral-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# MM Store bugfixes
# ---------------------------------------------------------------------------

def test_dedup_put_updates_value_and_bytes():
    """A re-put under a known key must adopt the new tuple and reconcile
    byte accounting (the old code silently kept the stale value AND the
    stale size)."""
    s = MMStore()
    s.put("k", "old", 100)
    s.put("k", "new", 60)
    assert s.get("k", record=False) == "new"
    assert s.nbytes("k") == 60
    assert s.stats.bytes_stored == 60 == s.resident_bytes()
    assert s.stats.dedup_puts == 1 and s.stats.puts == 1


def test_dedup_put_growth_reconverges_budget():
    """A dedup re-put that GROWS the entry can push the store over
    budget — eviction must reconverge (and the re-put key, freshly
    touched, must not be the LRU victim)."""
    s = MMStore(capacity_bytes=200)
    s.put("k1", b"a", 100)
    s.put("k2", b"b", 100)
    s.put("k1", b"A", 180)          # 280 > 200 -> evict LRU (k2)
    assert s.contains("k1") and not s.contains("k2")
    assert s.stats.bytes_stored == 180 == s.resident_bytes()
    assert s.stats.evictions == 1


def test_oversized_new_put_rejected():
    """An entry that alone exceeds capacity can never fit: admitting it
    would hold bytes_stored above budget forever (the old `len > 1`
    eviction guard did exactly that). It must be rejected and counted."""
    s = MMStore(capacity_bytes=100)
    s.put("big", b"x", 150)
    assert len(s) == 0 and s.stats.bytes_stored == 0
    assert s.stats.rejected_puts == 1 and s.stats.puts == 0


def test_single_oversized_entry_is_evicted_not_retained():
    """The `len > 1` guard retained a lone over-budget entry forever.
    Grow an admitted entry past capacity via the dedup-put path: the
    evictor must now evict down to an EMPTY store rather than hold it."""
    s = MMStore(capacity_bytes=100)
    s.put("k", b"a", 50)
    s.put("k", b"A" * 3, 150)       # dedup-put grows past budget
    assert len(s) == 0
    assert s.stats.bytes_stored == 0 == s.resident_bytes()
    assert s.stats.evictions == 1


def test_pin_exempts_from_eviction_until_unpin():
    s = MMStore(capacity_bytes=100)
    s.put("k1", b"a", 60)
    assert s.pin("k1")
    s.put("k2", b"b", 60)           # over budget; k1 pinned -> k2 evicted
    assert s.contains("k1") and not s.contains("k2")
    s.unpin("k1")
    s.put("k3", b"c", 60)           # k1 evictable again -> k1 evicted
    assert s.contains("k3") and not s.contains("k1")
    assert s.stats.bytes_stored == 60 == s.resident_bytes()
    assert not s.pin("absent")      # nothing to pin


def test_unpin_reconverges_held_over_budget_store():
    """Pins may legitimately hold the store above budget; the release
    must immediately reconverge."""
    s = MMStore(capacity_bytes=100)
    s.put("k", b"a", 80)
    s.pin("k")
    s.put("k", b"A", 150)           # grown over budget but pinned: held
    assert s.contains("k") and s.stats.bytes_stored == 150
    s.unpin("k")
    assert len(s) == 0 and s.stats.bytes_stored == 0
    assert s.stats.evictions == 1


def test_store_bytes_invariant_random_ops():
    """bytes_stored == sum of resident entry sizes under arbitrary
    interleavings of put / dedup-put / get / pin / unpin (seeded
    deterministic sweep; the hypothesis variant below widens it)."""
    rng = random.Random(0)
    for cap in (None, 64, 256, 1024):
        s = MMStore(capacity_bytes=cap)
        pins = []
        for _ in range(400):
            op = rng.randrange(5)
            key = f"k{rng.randrange(8)}"
            if op == 0:
                s.put(key, b"v", rng.randrange(1, 200))
            elif op == 1:
                s.get(key, record=bool(rng.randrange(2)))
            elif op == 2:
                if s.pin(key):
                    pins.append(key)
            elif op == 3 and pins:
                s.unpin(pins.pop(rng.randrange(len(pins))))
            else:
                s.contains(key)
            assert s.stats.bytes_stored == s.resident_bytes()
            if cap is not None and not pins:
                assert s.stats.bytes_stored <= cap
        while pins:
            s.unpin(pins.pop())
        if cap is not None:
            assert s.stats.bytes_stored <= cap


def test_store_bytes_invariant_hypothesis():
    pytest.importorskip("hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    from conftest import hyp_max_examples

    @settings(max_examples=hyp_max_examples(60), deadline=None)
    @given(st.integers(16, 512),
           st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7),
                              st.integers(1, 300)),
                    min_size=1, max_size=120))
    def run(cap, ops):
        s = MMStore(capacity_bytes=cap)
        pinned = []
        for op, k, nb in ops:
            key = f"k{k}"
            if op == 0:
                s.put(key, nb, nb)
            elif op == 1:
                s.get(key, record=False)
            elif op == 2:
                if s.pin(key):
                    pinned.append(key)
            elif pinned:
                s.unpin(pinned.pop())
            assert s.stats.bytes_stored == s.resident_bytes()
            if not pinned:
                assert s.stats.bytes_stored <= cap

    run()


# ---------------------------------------------------------------------------
# EPPrefetcher: announce-time check vs fire-time consumption race
# ---------------------------------------------------------------------------

def _prefetch_rig(cfg_params, *, pin, capacity=None):
    cfg, _ = cfg_params
    loop = EventLoop()
    store = MMStore(capacity_bytes=capacity)
    cost = CostModel(cfg)
    return loop, store, EPPrefetcher(loop, store, cost,
                                     async_mode=True, pin=pin), cost


def test_prefetch_fire_time_eviction_routes_to_recompute(llava):
    """Unpinned prefetcher: an eviction between announce and fire used
    to hand Prefill a vanished entry while on_ready reported a clean
    transfer. The fire-time re-check must route through the recompute
    arm (with its modeled delay) and surface the event."""
    loop, store, pf, cost = _prefetch_rig(llava, pin=False, capacity=100)
    store.put("feat", b"f", 80)
    fired = []
    pf.notify(1, "feat", 8, on_ready=fired.append)
    store.put("other", b"o", 80)           # evicts "feat" mid-flight
    assert not store.contains("feat")
    loop.run()
    assert fired == [True]                 # consumer sees the recompute
    rec = pf.records[0]
    assert rec.evicted_in_flight and rec.recomputed
    assert pf.inflight_evictions == 1
    # the recompute delay landed on the loop clock after the announce
    assert loop.now >= cost.encode_time(8)


def test_prefetch_pin_protects_entry_until_fire(llava):
    """Pinned (default) prefetcher: the announce pins the feature so an
    interleaved eviction cannot vanish it; the fire releases the pin and
    normal LRU pressure resumes."""
    loop, store, pf, _ = _prefetch_rig(llava, pin=True, capacity=100)
    store.put("feat", b"f", 80)
    fired = []
    pf.notify(1, "feat", 8, on_ready=fired.append)
    store.put("other", b"o", 80)           # would evict "feat" if unpinned
    assert store.contains("feat")          # pin held it ("other" evicted)
    loop.run()
    assert fired == [False] and pf.inflight_evictions == 0
    assert not pf.records[0].evicted_in_flight
    # pin released at fire: the next over-budget put may claim it
    store.put("later", b"l", 80)
    assert not store.contains("feat")


def test_prefetch_sync_blocks_encode_async_does_not(llava):
    cfg, _ = llava
    store = MMStore()
    store.put("k", b"f", 64)
    cost = CostModel(cfg)
    a = EPPrefetcher(EventLoop(), store, cost, async_mode=True)
    s = EPPrefetcher(EventLoop(), store, cost, async_mode=False)
    assert a.notify(1, "k", 8, on_ready=lambda _r: None) == 0.0
    assert s.notify(1, "k", 8, on_ready=lambda _r: None) > 0.0


# ---------------------------------------------------------------------------
# EncodeEngine
# ---------------------------------------------------------------------------

def test_encode_engine_requires_frontend():
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        EncodeEngine(cfg, params, store=MMStore())


def test_encode_engine_dedup_and_metrics(llava):
    cfg, params = llava
    store = MMStore()
    eng = EncodeEngine(cfg, params, store=store, name="E0")
    r1 = Request(prompt_tokens=[1, 2], mm_payload=b"img", mm_tokens=8)
    r2 = Request(prompt_tokens=[3, 4], mm_payload=b"img", mm_tokens=8)
    k1, k2 = eng.encode_request(r1), eng.encode_request(r2)
    assert k1 == k2 == FE.content_hash(b"img")
    assert store.stats.puts == 1 and store.stats.hits == 1
    assert eng.metrics.value("encode_requests_total", engine="E0") == 2
    assert eng.metrics.value("encode_dedup_total", engine="E0") == 1
    assert eng.metrics.value("encode_tokens_total", engine="E0") == 8


def test_recompute_is_bit_identical_to_stored_features(llava):
    cfg, params = llava
    store = MMStore()
    eng = EncodeEngine(cfg, params, store=store)
    r = Request(prompt_tokens=[1], mm_payload=b"img", mm_tokens=8)
    key = eng.encode_request(r)
    stored = store.get(key, record=False)
    again = eng.compute_features(b"img", 8)
    assert stored.dtype == np.float32
    np.testing.assert_array_equal(stored, again)


def test_mm_key_run_is_deterministic_and_disjoint_from_vocab():
    a = FE.mm_key_run("deadbeef", 16)
    assert a == FE.mm_key_run("deadbeef", 16)
    assert len(a) == 16 and len(set(a)) == 16
    assert all(t < 0 for t in a)           # never collides with token ids
    assert a != FE.mm_key_run("cafebabe", 16)
    assert a == FE.mm_key_run("deadbeef", 32)[:16]


# ---------------------------------------------------------------------------
# Cluster: E->P overlap arms + (mm-hash, token-run) prefix reuse
# ---------------------------------------------------------------------------

def _mm_cluster(cfg, params, arm, tracer=None):
    return EPDCluster(cfg, params, max_batch=2, max_len=96, paged=True,
                      page_size=8, prefix_cache=True, ep_overlap=arm,
                      tracer=tracer)


def test_overlap_arms_bit_identical_and_accounted(llava):
    """The three E->P hand-off arms differ ONLY in modeled accounting:
    greedy output must be bit-identical across them and match the
    monolithic engine; every traced run must satisfy the components-
    sum-to-e2e ledger invariant; and async must never charge MORE
    E->P exposure than sync."""
    cfg, params = llava
    prompt = list(range(5, 15))
    outs, xfer = {}, {}
    for arm in ("async", "sync", "inline"):
        tr = Tracer(enabled=True)
        cl = _mm_cluster(cfg, params, arm, tracer=tr)
        r = Request(prompt_tokens=list(prompt), max_new_tokens=5,
                    mm_payload=b"arm-img", mm_tokens=8, mm_pos=4)
        cl.submit(r)
        cl.run_until_done()
        cl.acc.check_all()
        outs[arm] = list(r.output_tokens)
        row = cl.attribution()["requests"][0]
        xfer[arm] = row["components_ms"]["transfer"]
        if arm != "inline":
            assert any(s.name == "ep.prefetch" for s in tr.spans)
        cl.prefill_engine.assert_no_page_leaks()
        cl.decode_engine.assert_no_page_leaks()
    mono = Engine(cfg, params, max_batch=2, max_len=96)
    rm = Request(prompt_tokens=list(prompt), max_new_tokens=5,
                 mm_payload=b"arm-img", mm_tokens=8, mm_pos=4)
    mono.run_request(rm)
    assert outs["async"] == outs["sync"] == outs["inline"] \
        == list(rm.output_tokens)
    # P->D exposure is identical across arms, so the ordering isolates
    # the E->P charge: inline none < async hidden <= sync serial
    assert xfer["inline"] < xfer["async"] <= xfer["sync"]


def test_prefix_key_composes_mm_dedup_with_kv_reuse(llava):
    """Same image + same prompt prefix, longer suffix: the (mm-hash,
    token-run) radix key must cover the whole image run, so the second
    request skips the encode forward AND the feature fetch outright —
    while still decoding the same tokens a cold cluster produces."""
    cfg, params = llava
    cl = _mm_cluster(cfg, params, "async")
    r1 = Request(prompt_tokens=list(range(5, 15)), max_new_tokens=4,
                 mm_payload=b"reuse-img", mm_tokens=8, mm_pos=4)
    cl.submit(r1)
    cl.run_until_done()
    assert cl.report.encode_skips == 0
    r2 = Request(prompt_tokens=list(range(5, 15)) + [77, 78],
                 max_new_tokens=4, mm_payload=b"reuse-img",
                 mm_tokens=8, mm_pos=4)
    cl.submit(r2)
    cl.run_until_done()
    assert cl.report.encode_skips == 1
    assert cl.store.stats.puts == 1                  # no second encode
    assert cl.metrics.value("encode_requests_total", engine="E0") == 1
    # correctness: a cold cluster (no reuse at all) agrees bit-for-bit
    cold = _mm_cluster(cfg, params, "async")
    rc = Request(prompt_tokens=list(range(5, 15)) + [77, 78],
                 max_new_tokens=4, mm_payload=b"reuse-img",
                 mm_tokens=8, mm_pos=4)
    cold.submit(rc)
    cold.run_until_done()
    assert cold.report.encode_skips == 0
    assert list(r2.output_tokens) == list(rc.output_tokens)
    cl.prefill_engine.assert_no_page_leaks()
    cl.decode_engine.assert_no_page_leaks()


def test_overlap_gauge_and_records(llava):
    cfg, params = llava
    cl = _mm_cluster(cfg, params, "async")
    r = Request(prompt_tokens=list(range(5, 15)), max_new_tokens=3,
                mm_payload=b"gauge-img", mm_tokens=8, mm_pos=4)
    cl.submit(r)
    cl.run_until_done()
    assert len(cl.prefetcher.records) == 1
    ratio = cl.metrics.value("ep_overlap_ratio")
    assert 0.0 <= ratio <= 1.0
    assert ratio == pytest.approx(cl.prefetcher.mean_overlap_ratio)


def test_cluster_rejects_bad_ep_args(llava):
    cfg, params = llava
    with pytest.raises(ValueError):
        EPDCluster(cfg, params, ep_overlap="magic")
    with pytest.raises(ValueError):
        EPDCluster(cfg, params, n_encode=0)

"""Real-compute EPD mini-cluster: disaggregated E/P/D with actual tensors."""
import jax
import pytest

from repro.configs import get_config
from repro.core.cluster import EPDCluster
from repro.models.model import init_params
from repro.serving.engine import Engine
from repro.serving.request import Request


@pytest.fixture(scope="module")
def llava():
    cfg = get_config("llava-next-mistral-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.mark.parametrize("paged", [False, True])
def test_epd_pipeline_end_to_end(llava, paged):
    cfg, params = llava
    cluster = EPDCluster(cfg, params, max_batch=4, max_len=64,
                         paged=paged, page_size=8)
    reqs = [Request(prompt_tokens=list(range(3, 10)), max_new_tokens=5,
                    mm_payload=b"img-%d" % (i % 2), mm_tokens=8)
            for i in range(4)]
    reqs.append(Request(prompt_tokens=list(range(20, 30)), max_new_tokens=5))
    for r in reqs:
        cluster.submit(r)
    done = cluster.run_until_done()
    assert len(done) == 5
    for r in done:
        assert len(r.output_tokens) == 5
    # 2 unique images across 4 mm requests -> 2 encodes, 2 dedup hits
    assert cluster.store.stats.puts == 2
    assert cluster.store.stats.hits == 2
    if paged:
        # leak audit: both pools drained, every refcount accounted for
        cluster.prefill_engine.assert_no_page_leaks()
        cluster.decode_engine.assert_no_page_leaks()
        assert cluster.prefill_engine.pool.n_used == 0
        assert cluster.decode_engine.pool.n_used == 0


def test_epd_equals_monolithic_outputs(llava):
    """Disaggregated E->P->D must produce the SAME tokens as the
    monolithic engine (the paper's correctness premise: disaggregation is
    a systems change, not a model change)."""
    cfg, params = llava
    req_a = Request(prompt_tokens=[5, 6, 7, 8], max_new_tokens=6,
                    mm_payload=b"same-image", mm_tokens=8)
    req_b = Request(prompt_tokens=[5, 6, 7, 8], max_new_tokens=6,
                    mm_payload=b"same-image", mm_tokens=8)
    cluster = EPDCluster(cfg, params, max_batch=2, max_len=64)
    cluster.submit(req_a)
    cluster.run_until_done()

    mono = Engine(cfg, params, max_batch=2, max_len=64)
    mono.run_request(req_b)
    assert req_a.output_tokens == req_b.output_tokens


def test_fault_tolerant_recompute(llava):
    cfg, params = llava
    cluster = EPDCluster(cfg, params, max_batch=2, max_len=64,
                         paged=True, page_size=8)
    r1 = Request(prompt_tokens=[1, 2, 3], max_new_tokens=4,
                 mm_payload=b"imgX", mm_tokens=8)
    cluster.submit(r1)
    cluster.run_until_done()
    # corrupt the store entry; a dedup-hit request must recompute locally
    key = list(cluster.store._data.keys())[0]
    cluster.store.inject_fault(key)
    r2 = Request(prompt_tokens=[1, 2, 3], max_new_tokens=4,
                 mm_payload=b"imgX", mm_tokens=8)
    cluster.submit(r2)
    cluster.run_until_done()
    assert cluster.report.recomputes == 1
    assert r2.output_tokens == r1.output_tokens    # recompute is exact
    # the recompute path must release its pages like any other request
    cluster.prefill_engine.assert_no_page_leaks()
    cluster.decode_engine.assert_no_page_leaks()


def test_kv_plans_recorded(llava):
    cfg, params = llava
    cluster = EPDCluster(cfg, params, max_batch=2, max_len=64,
                         kv_scheme="grouped")
    cluster.submit(Request(prompt_tokens=[1, 2, 3], max_new_tokens=3))
    cluster.run_until_done()
    assert len(cluster.report.kv_plans) == 1
    p = cluster.report.kv_plans[0]
    assert sum(g.nbytes for g in p.groups) > 0
    assert 0.0 <= p.overlap_ratio <= 1.0

"""llama4-scout-17b-a16e [moe] — 16 experts top-1, early-fusion vision.

[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.configs.base import FrontendConfig, LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    pattern=(LayerSpec("attn", "moe"),),
    moe=MoEConfig(n_experts=16, top_k=1),
    # early-fusion multimodal: vision frontend STUB provides patch embeddings
    frontend=FrontendConfig(kind="vision", tokens_per_item=576, feature_dim=1408),
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

"""Unit tests for the EPD-Serve core: MM Store, KV transfer planner,
deployments, scheduler, co-location model, cost model."""
import pytest

from repro.core.colocation import (STAGE_MIX, interference_heatmap,
                                   stage_slowdown)
from repro.core.costmodel import RDMA, V5E, CostModel
from repro.core.deployment import PAPER_DEPLOYMENTS, parse, scale
from repro.core.events import EventLoop
from repro.core.kv_transfer import choose_group_size, plan
from repro.core.mm_store import MMStore
from repro.core.scheduler import Router
from repro.configs import get_config
from repro.serving.request import Request


# ---------------------------------------------------------------------------
# events
# ---------------------------------------------------------------------------

def test_event_loop_ordering():
    loop = EventLoop()
    seen = []
    loop.at(2.0, lambda: seen.append("b"))
    loop.at(1.0, lambda: seen.append("a"))
    loop.after(3.0, lambda: seen.append("c"))
    loop.run()
    assert seen == ["a", "b", "c"]
    assert loop.now == 3.0


# ---------------------------------------------------------------------------
# MM store
# ---------------------------------------------------------------------------

def test_mm_store_dedup_and_hits():
    s = MMStore()
    s.put("k1", "v1", 100)
    s.put("k1", "v1", 100)               # dedup
    assert s.stats.dedup_puts == 1
    assert s.get("k1") == "v1"
    assert s.get("nope") is None
    assert s.stats.hits == 1 and s.stats.misses == 1
    assert 0.0 < s.stats.hit_rate < 1.0


def test_mm_store_lru_eviction():
    s = MMStore(capacity_bytes=250)
    s.put("a", 1, 100)
    s.put("b", 2, 100)
    s.get("a")                            # refresh a
    s.put("c", 3, 100)                    # evicts b (LRU)
    assert s.contains("a") and s.contains("c")
    assert not s.contains("b")
    assert s.stats.evictions == 1
    assert s.stats.bytes_stored <= 250


def test_mm_store_fault_injection():
    s = MMStore()
    s.put("k", "v", 10)
    s.inject_fault("k")
    assert s.get("k") is None             # one faulted read
    assert s.get("k") == "v"              # subsequent reads recover
    assert s.stats.faults_injected == 1


# ---------------------------------------------------------------------------
# KV transfer planner (paper §3.3)
# ---------------------------------------------------------------------------

PLAN_KW = dict(n_layers=32, bytes_per_layer=9e9 / 32,
               per_layer_compute=6.8 / 32, handshake=13e-3, link_bw=12.5e9)


def test_kv_plan_schemes_ordering():
    one = plan("one_shot", **PLAN_KW)
    lw = plan("layer_wise", **PLAN_KW)
    gr = plan("grouped", **PLAN_KW)
    # grouped hides almost everything; layer-wise partially; one-shot nothing
    assert gr.overlap_ratio > 0.95
    assert one.overlap_ratio == 0.0
    assert lw.overlap_ratio < gr.overlap_ratio
    # grouped finishes earliest end-to-end
    assert gr.total_done <= lw.total_done
    assert gr.total_done <= one.total_done
    # grouped bandwidth >= layer-wise (handshake amortization)
    assert gr.effective_bandwidth >= lw.effective_bandwidth


def test_kv_plan_layer_coverage():
    for scheme in ("one_shot", "layer_wise", "grouped"):
        p = plan(scheme, **PLAN_KW)
        covered = sorted((g.start, g.end) for g in p.groups)
        # contiguous cover of [0, 32)
        assert covered[0][0] == 0 and covered[-1][1] == 32
        for (s1, e1), (s2, e2) in zip(covered, covered[1:]):
            assert e1 == s2
        # payload conserved
        assert sum(g.nbytes for g in p.groups) == pytest.approx(9e9)


def test_kv_plan_blocking_handshake_stretches_prefill():
    lw = plan("layer_wise", **PLAN_KW)
    assert lw.prefill_end > lw.prefill_time
    gr = plan("grouped", **PLAN_KW)
    assert gr.prefill_end == gr.prefill_time


def test_choose_group_size_regimes():
    # compute-bound: handshake must hide inside a group's compute
    g = choose_group_size(32, per_layer_compute=0.2, handshake=0.5,
                          per_layer_transfer=0.01)
    assert g >= 3
    # wire-bound: amortize handshake
    g2 = choose_group_size(32, per_layer_compute=0.001, handshake=0.05,
                           per_layer_transfer=0.01)
    assert g2 > 1


def test_choose_group_size_single_layer():
    # n_layers=1 must always be a single group regardless of regime
    assert choose_group_size(1, 0.2, 0.5, 0.01) == 1
    assert choose_group_size(1, 0.001, 0.5, 10.0) == 1
    # and never exceeds half the stack
    for n in (2, 3, 5):
        assert 1 <= choose_group_size(n, 0.001, 5.0, 0.01) <= max(n // 2, 1)


def test_kv_plan_single_layer_all_schemes():
    for scheme in ("one_shot", "layer_wise", "grouped"):
        p = plan(scheme, n_layers=1, bytes_per_layer=1e6,
                 per_layer_compute=1e-3, handshake=2e-3, link_bw=1e9)
        assert len(p.groups) == 1
        assert p.groups[0].start == 0 and p.groups[0].end == 1
        assert p.groups[0].nbytes == pytest.approx(1e6)


def test_kv_plan_grouped_wire_bound_taper():
    # wire-bound (t_x >> t_c): grouped must still cover all layers and
    # taper the final group to a single layer so the exposed tail is the
    # last layer's KV only.
    p = plan("grouped", n_layers=32, bytes_per_layer=1e8,
             per_layer_compute=1e-4, handshake=5e-3, link_bw=1e9)
    assert p.groups[0].start == 0 and p.groups[-1].end == 32
    if len(p.groups) > 1:
        assert p.groups[-1].end - p.groups[-1].start == 1
    for g1, g2 in zip(p.groups, p.groups[1:]):
        assert g1.end == g2.start


def test_kv_plan_group_size_at_least_n_layers():
    # explicit group_size >= n_layers degenerates to one group
    for gsz in (4, 7, 100):
        p = plan("grouped", n_layers=4, bytes_per_layer=1e6,
                 per_layer_compute=1e-3, handshake=1e-3, link_bw=1e9,
                 group_size=gsz)
        assert len(p.groups) == 1
        assert (p.groups[0].start, p.groups[0].end) == (0, 4)
        assert p.groups[0].nbytes == pytest.approx(4e6)


def test_kv_plan_page_granularity():
    # page_bytes rounds each layer's payload up to whole pages, so every
    # group is page-aligned and the padded payload is >= the raw payload
    page = 64e3
    for scheme in ("one_shot", "layer_wise", "grouped"):
        p = plan(scheme, n_layers=8, bytes_per_layer=1e5,
                 per_layer_compute=1e-3, handshake=1e-3, link_bw=1e9,
                 page_bytes=page)
        for g in p.groups:
            assert g.nbytes % page == pytest.approx(0.0, abs=1e-6)
        assert sum(g.nbytes for g in p.groups) >= 8 * 1e5
    # page_bytes=0 keeps the exact payload (back-compat)
    p0 = plan("grouped", n_layers=8, bytes_per_layer=1e5,
              per_layer_compute=1e-3, handshake=1e-3, link_bw=1e9)
    assert sum(g.nbytes for g in p0.groups) == pytest.approx(8e5)


# ---------------------------------------------------------------------------
# deployments
# ---------------------------------------------------------------------------

def test_parse_deployments():
    for name in PAPER_DEPLOYMENTS:
        dep = parse(name)
        stages = set()
        for i in dep.instances:
            stages.update(i.stages)
        assert stages == {"E", "P", "D"}, name

    assert parse("TP1").n_chips == 1
    assert parse("TP2").n_chips == 2
    assert parse("TP2").instances[0].tp == 2
    assert parse("E-P-D").n_chips == 3
    assert parse("(E-PD)").n_chips == 1
    assert parse("(E-P)-D").n_chips == 2
    ep_d = parse("EP-D")
    assert ep_d.instances[0].monolithic
    assert not parse("(E-P)-D").instances[0].monolithic
    colo = parse("(E-D)-P")
    assert colo.instances[0].coloc_group == colo.instances[1].coloc_group >= 0
    assert colo.instances[2].coloc_group == -1


def test_scale_replicas():
    dep = scale(parse("(E-P)-D"), 2)
    assert dep.n_chips == 4
    assert len(dep.instances) == 6
    groups = {i.coloc_group for i in dep.instances if i.coloc_group >= 0}
    assert len(groups) == 2               # each replica its own chip


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_router_multipath():
    r = Router(parse("E-P-D"))
    mm = Request(prompt_tokens=[1], mm_payload=b"x", mm_tokens=10)
    txt = Request(prompt_tokens=[1])
    assert r.path(mm) == ["E", "P", "D"]
    assert r.path(txt) == ["P", "D"]


def test_router_least_loaded():
    dep = scale(parse("E-P-D"), 2)
    r = Router(dep)
    names = [i.name for i in dep.stage_instances("P")]
    r.on_busy_until(names[0], 5.0)
    picked = r.pick("P", now=0.0)
    assert picked.spec.name == names[1]
    # prefer pins affinity
    assert r.pick("P", now=0.0, prefer=names[0]).spec.name == names[0]


# ---------------------------------------------------------------------------
# co-location interference (paper Fig. 6 structure)
# ---------------------------------------------------------------------------

def test_interference_structure():
    h = interference_heatmap()
    # like-with-like worst; complementary mild
    assert h[("P", "P")] > h[("P", "D")]
    assert h[("D", "D")] > h[("D", "E")]
    assert h[("E", "P")] > h[("E", "D")]
    for k, v in h.items():
        assert v >= 1.0
    # no concurrent stage => no slowdown
    assert stage_slowdown("P", []) == 1.0


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_cost_model_monotonic():
    cm = CostModel(get_config("openpangu-7b-vl"))
    assert cm.prefill_time(2048) > cm.prefill_time(256)
    assert cm.encode_time(2000) > cm.encode_time(100)
    assert cm.decode_step_time(64, 1000) > cm.decode_step_time(1, 1000)
    # decode is memory-bound: time ~ flat in batch until compute kicks in
    assert cm.decode_step_time(2, 500) < 2 * cm.decode_step_time(1, 500)
    # TP penalty: TP2 on 2 chips is less than 2x faster
    assert cm.prefill_time(2048, chips=2, tp=2) > \
        cm.prefill_time(2048, chips=2, tp=1) / 1.0 * 0.5
    # sliding window caps decode KV traffic
    mx = CostModel(get_config("mixtral-8x7b"))
    assert mx.decode_step_time(1, 100_000) == \
        pytest.approx(mx.decode_step_time(1, mx.cfg.sliding_window), rel=1e-6)


def test_paper_table3_shape():
    """E-P overlap is ~100% at mainstream resolutions, <100% only at 4K."""
    cm = CostModel(get_config("openpangu-7b-vl"))
    from repro.models.frontend import PAPER_RESOLUTION_TOKENS
    for res, n in PAPER_RESOLUTION_TOKENS.items():
        nb = cm.feature_bytes(n)
        tx = cm.feature_transfer_time(nb)
        sc = cm.dispatch_latency(nb)
        ratio = min(tx, sc) / tx
        if n < 10_000:
            assert ratio == 1.0, res
        else:
            assert 0.98 < ratio < 1.0, res


def test_router_on_idle_drains_stale_busy_until():
    """busy_until is only ever max'd by on_busy_until: without the idle
    hook a finished instance keeps its stale backlog forever and pick()
    is biased away from it. on_idle collapses the estimate so a drained
    instance's load returns to ~0."""
    dep = scale(parse("E-P-D"), 2)
    r = Router(dep)
    names = [i.name for i in dep.stage_instances("P")]
    rid = "req-1"
    r.on_enqueue(names[0], 100.0, rid=rid)
    r.on_start(names[0], 100.0, rid=rid)
    r.on_busy_until(names[0], 50.0)
    # instance finished its work at t=60, but the estimate never drains:
    assert r.status[names[0]].load(now=60.0) == 0.0  # backlog clamped...
    assert r.status[names[0]].load(now=10.0) > 0.0   # ...but stale before t=50
    r.on_idle(names[0], 10.0)
    assert r.status[names[0]].busy_until == 10.0
    assert r.status[names[0]].load(now=10.0) == pytest.approx(0.0)
    # and pick() sees it as least-loaded again
    r.on_busy_until(names[1], 5.0)
    assert r.pick("P", now=10.0).spec.name == names[0]
    # on_idle never moves the estimate FORWARD
    r.on_idle(names[0], 99.0)
    assert r.status[names[0]].busy_until == 10.0


def test_router_ledger_caps_double_retirement():
    """on_start(tokens=N) followed by chunk-granular on_prefill_progress
    for the same N (the double-retirement bug) must not drag the
    aggregate below other requests' outstanding work."""
    dep = parse("E-P-D")
    r = Router(dep)
    name = dep.stage_instances("P")[0].name
    r.on_enqueue(name, 64.0, rid="a")
    r.on_enqueue(name, 32.0, rid="b")
    # request a reports its 64 tokens TWICE: once at start, once chunked
    r.on_start(name, 64.0, rid="a")
    for _ in range(4):
        r.on_prefill_progress(name, 16.0, rid="a")
    st = r.status[name]
    assert st.pending_tokens == 32.0          # b's work survives intact
    assert "a" not in st.pending_by_req
    r.on_start(name, 0.0, rid="b")
    for _ in range(2):
        r.on_prefill_progress(name, 16.0, rid="b")
    assert st.pending_tokens == 0.0
    assert st.pending_by_req == {}

"""Architecture registry: ``get_config(name)`` resolves an assigned arch id."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (EncoderConfig, FrontendConfig, InputShape,
                                LayerSpec, ModelConfig, MoEConfig, SHAPES,
                                SSMConfig)

# arch id -> module name in this package
_MODULES = {
    "glm4-9b": "glm4_9b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "deepseek-7b": "deepseek_7b",
    "llama3.2-1b": "llama3_2_1b",
    "whisper-base": "whisper_base",
    "mamba2-370m": "mamba2_370m",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "smollm-135m": "smollm_135m",
    "mixtral-8x7b": "mixtral_8x7b",
    # the paper's own model (estimated geometry, see module docstring)
    "openpangu-7b-vl": "openpangu_7b_vl",
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "openpangu-7b-vl")
ALL_ARCHS = tuple(_MODULES)

_cache: Dict[str, ModelConfig] = {}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    if name not in _cache:
        mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
        _cache[name] = mod.CONFIG
    return _cache[name]


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "FrontendConfig", "EncoderConfig",
    "LayerSpec", "InputShape", "SHAPES", "ASSIGNED_ARCHS", "ALL_ARCHS",
    "get_config", "get_shape",
]

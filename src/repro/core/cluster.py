"""REAL-compute EPD mini-cluster.

Wires actual JAX ``Engine`` instances (repro.serving.engine) through the
same EPD-Serve machinery the simulator uses — MM Store, modality-aware
router, E->P prefetch bookkeeping, P->D grouped KV transfer planning —
so the disaggregation logic is exercised end-to-end with real tensors on
CPU-scale configs. This is deliverable (b)'s serving driver and the
integration-test backbone.

Stage mapping:
* Encode instance  — runs the (stubbed) frontend + owns the MM Store put.
* Prefill instance — fetches features by hash from the MM Store
  (recomputing on a miss — fault-tolerance path), runs real prefill,
  exports the prefilled cache pytree (the "KV payload").
* Decode instance  — imports caches via the grouped transfer planner
  (payload bytes measured from the actual arrays) and continuous-batches
  decode steps.

Co-located stages share one Engine's params but keep separate logical
queues, mirroring the paper's logical-isolation/physical-co-location.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.costmodel import CostModel, Hardware, V5E
from repro.core.kv_transfer import (TransferPlan, plan as kv_plan,
                                    plan_chunked as kv_plan_chunked)
from repro.core.mm_store import MMStore
from repro.models import frontend as FE
from repro.serving.engine import Engine
from repro.serving.kv_pool import PoolExhausted
from repro.serving.request import Request


def cache_nbytes(caches) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))


@dataclass
class ClusterReport:
    completed: List[Request] = field(default_factory=list)
    kv_plans: List[TransferPlan] = field(default_factory=list)
    recomputes: int = 0
    # page-level preemption on the Decode engine
    preemptions: int = 0
    swapped_pages: int = 0           # host-link pages moved (out + in)
    admission_denials: int = 0       # inserts denied by the decode pool

    @property
    def mean_kv_overlap(self) -> float:
        if not self.kv_plans:
            return 1.0
        return sum(p.overlap_ratio for p in self.kv_plans) / len(self.kv_plans)


class EPDCluster:
    """E / P / D as separate engines over shared params (disaggregated)."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 128, kv_scheme: str = "grouped",
                 hw: Hardware = V5E, paged: bool = False,
                 page_size: int = 16, prefix_cache: bool = False,
                 n_prefill_pool_pages: Optional[int] = None,
                 chunked_prefill: bool = False, prefill_chunk: int = 32,
                 preemption: bool = False,
                 n_decode_pool_pages: Optional[int] = None):
        self.cfg = cfg
        self.store = MMStore()
        self.cost = CostModel(cfg, hw,
                              page_tokens=page_size if paged else 0)
        self.kv_scheme = kv_scheme
        self.paged = paged
        self.chunked_prefill = chunked_prefill
        # Prefill engine: batch 1 (prefill is per-request); carries the
        # radix prefix cache when enabled (hits skip prefill compute for
        # the shared pages and the transfer planner charges suffix-only)
        # and the chunked-prefill window (each chunk's pages stream to
        # Decode while the next chunk computes).
        # Decode engine: the continuous-batching instance.
        self.prefill_engine = Engine(cfg, params, max_batch=1,
                                     max_len=max_len, paged=paged,
                                     page_size=page_size,
                                     prefix_cache=prefix_cache,
                                     n_pool_pages=n_prefill_pool_pages,
                                     chunked_prefill=chunked_prefill,
                                     prefill_chunk=prefill_chunk)
        # Decode engine: preemption=True turns decode-side pool pressure
        # into page-level swap-to-host + resume instead of a pool error;
        # n_decode_pool_pages sizes the pool below worst-case for
        # overload experiments.
        self.decode_engine = Engine(cfg, params, max_batch=max_batch,
                                    max_len=max_len, paged=paged,
                                    page_size=page_size,
                                    n_pool_pages=n_decode_pool_pages,
                                    preemption=preemption)
        self.report = ClusterReport()
        self._pending: List[Request] = []

    # ---- Encode stage ----
    def encode(self, req: Request) -> Optional[str]:
        if not req.is_multimodal:
            return None
        key = hashlib.sha256(req.mm_payload).hexdigest()
        if not self.store.contains(key):
            self.store.stats.misses += 1
            feats = FE.stub_embeddings(self.cfg, req.mm_payload,
                                       req.mm_tokens or None)
            self.store.put(key, np.asarray(feats), feats.size * 4)
        else:
            # dedup: skip Encode entirely (cross-request reuse, §3.2);
            # contains() doesn't consume injected faults — those hit the
            # Prefill-side fetch, exercising the recompute path.
            self.store.stats.hits += 1
        return key

    # ---- Prefill stage (with FT recompute on store miss) ----
    def prefill(self, req: Request, key: Optional[str]):
        mm = None
        enc = None
        if key is not None:
            feats = self.store.get(key, record=False)
            if feats is None:
                # fault tolerance: recompute locally (paper §3.2)
                feats = np.asarray(FE.stub_embeddings(
                    self.cfg, req.mm_payload, req.mm_tokens or None))
                self.report.recomputes += 1
            feats = jnp.asarray(feats)[None]
            if self.cfg.encoder is not None:
                enc = feats
            else:
                mm = feats
        first, caches = self.prefill_engine.prefill_request(req, mm, enc)
        return first, caches

    # ---- P->D transfer + Decode import ----
    def transfer_and_insert(self, req: Request, caches, first: int) -> None:
        # paged payloads already carry their page-granular byte count;
        # dense payloads are measured from the actual arrays.
        nbytes = getattr(caches, "kv_nbytes", None)
        if nbytes is None:
            nbytes = cache_nbytes(caches)
        # prefix-cache hits shrink the prefill the transfer overlaps with:
        # only the computed suffix counts as per-layer compute.
        cached = getattr(caches, "cached_tokens", 0)
        chunks = getattr(caches, "chunks", None)
        if chunks:
            # streaming chunked prefill: segment k's pages (measured from
            # the actual payload) ship while segment k+1 computes; a
            # cached-prefix segment (0 computed tokens) is ready at t=0
            per_page = nbytes / max(len(caches.page_ids), 1)
            p = kv_plan_chunked(
                chunk_bytes=[n_pg * per_page for _, n_pg in chunks],
                chunk_compute=self.cost.chunk_prefill_times(
                    req.total_prompt_len, [toks for toks, _ in chunks],
                    cached_prefix=cached),
                handshake=self.cost.hw.handshake,
                link_bw=self.cost.hw.link_bw,
                page_bytes=self.cost.kv_page_bytes())
        else:
            p = kv_plan(self.kv_scheme,
                        n_layers=self.cfg.n_layers,
                        bytes_per_layer=nbytes / self.cfg.n_layers,
                        per_layer_compute=self.cost.per_layer_prefill_time(
                            req.total_prompt_len, cached_prefix=cached),
                        handshake=self.cost.hw.handshake,
                        link_bw=self.cost.hw.link_bw,
                        page_bytes=self.cost.kv_page_bytes_per_layer())
        # insert may preempt a decode victim to make room; only a
        # successful admission records the transfer plan
        self.decode_engine.insert(req, caches, first)
        self.report.kv_plans.append(p)

    # ---- full pipeline ----
    def submit(self, req: Request) -> bool:
        """Run E->P and admit into Decode. Returns False when the decode
        pool denied admission (exhausted even after preemption would
        leave no active slot): the request re-queues at the front and
        its payload is released — it re-prefills on retry (the prefix
        cache, when enabled, makes that cheap)."""
        if not self.decode_engine.free_slots():
            self._pending.append(req)
            return True
        key = self.encode(req)
        first, caches = self.prefill(req, key)
        try:
            self.transfer_and_insert(req, caches, first)
        except PoolExhausted:
            # insert raises before any mutation: no token was recorded
            if self.paged:
                self.prefill_engine.release_payload(caches)
            self.report.admission_denials += 1
            self._pending.insert(0, req)
            return False
        return True

    def run_until_done(self, max_steps: int = 1000) -> List[Request]:
        steps = 0
        done: List[Request] = []
        while ((self.decode_engine.n_active or self._pending
                or self.decode_engine.preempted) and steps < max_steps):
            for r, _t, d in self.decode_engine.decode_step():
                if d:
                    done.append(r)
            while self._pending and self.decode_engine.free_slots():
                if not self.submit(self._pending.pop(0)):
                    break                  # denied: wait for decode to drain
            steps += 1
        self.report.completed.extend(done)
        self.report.preemptions = self.decode_engine.preempt_count
        self.report.swapped_pages = (
            self.decode_engine.swap_out_pages_total
            + self.decode_engine.swap_in_pages_total)
        return done

from repro.kernels.ssd_scan.ops import ssd_ref, ssd_scan, ssd_sequential

__all__ = ["ssd_scan", "ssd_ref", "ssd_sequential"]

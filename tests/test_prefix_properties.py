"""Hypothesis property tests for the radix-tree prefix cache:
ref-count conservation, branch integrity, and match/page agreement under
arbitrary interleavings of insert / release / evict."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.serving.kv_pool import PagePool
from repro.serving.prefix_cache import PrefixCache


# ---------------------------------------------------------------------------
# hypothesis: ref-count + branch-integrity invariants
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["insert", "release", "evict"]),
                          st.integers(0, 7), st.integers(1, 20)),
                min_size=1, max_size=40),
       st.integers(2, 8))
def test_tree_refcount_invariant(ops, page):
    """Total refs per page == retaining requests + tree retentions, under
    arbitrary interleavings of insert / release / evict; inserted
    sequences stay matchable unless evicted; unrelated branches survive."""
    pool = PagePool(257, page_size=page)
    cache = PrefixCache(page, pool)
    live = {}                                     # rid -> (tokens, ids)
    rid = 0
    for op, fam, ln in ops:
        if op == "insert" and pool.n_free >= pool.pages_for(ln):
            # family gives shared prefixes; ln the total length
            tokens = [fam * 1000 + j // 3 for j in range(ln)]
            ids = pool.alloc(pool.pages_for(ln))
            cache.insert(tokens, ids)
            live[rid] = (tokens, ids)
            rid += 1
        elif op == "release" and live:
            k = sorted(live)[fam % len(live)]
            _, ids = live.pop(k)
            pool.free(ids)
        elif op == "evict":
            cache.evict(ln)
        # invariant: allocator state == request holders + tree retentions
        pool.assert_balanced(
            [ids for _, ids in live.values()] + [cache.retained_pages()])
    # match structure agrees with the refs it takes: one page per full
    # matched page, a CoW source iff the match ends inside a page (same-
    # family sequences share prefixes, so a match may run past one
    # request's own full pages into a longer relative's retention)
    for tokens, _ in live.values():
        m = cache.match_and_ref(tokens)
        assert m.n_tokens <= len(tokens)
        assert m.n_full_pages == m.n_tokens // page
        assert (m.cow_src is None) == (m.n_tokens % page == 0)
        pool.unref(m.page_ids)
        if m.cow_src is not None:
            pool.unref([m.cow_src])


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(st.integers(0, 3), min_size=1, max_size=24),
                min_size=2, max_size=8))
def test_tree_match_is_true_prefix(seqs):
    """match_len never exceeds the true longest common prefix with some
    inserted sequence (no cross-branch corruption)."""
    page = 4
    cache = PrefixCache(page)
    inserted = []
    for s in seqs:
        cache.insert(s)
        inserted.append(list(s))
    for s in seqs:
        probe = list(s) + [99]
        n = cache.match_len(probe)
        best = 0
        for t in inserted:
            full = (len(t) // page) * page
            lcp = 0
            while (lcp < min(len(probe), len(t)) and probe[lcp] == t[lcp]):
                lcp += 1
            best = max(best, min(lcp, full))
        assert n == best



"""E->P asynchronous feature prefetching (paper §3.2).

Mechanism: when Encode finishes, only the *feature hash* is pushed to the
target Prefill instance (cheap, ~O(100 B)). The Prefill listener then
pulls the feature from the MM Store in the background while the request
sits in Prefill's queue / while earlier requests compute — so transfer
latency is hidden under scheduling latency. On a store miss (fault), the
Prefill instance recomputes the feature locally (fault tolerance).

``overlap_ratio`` reproduces the paper's Table 3 metric:
    hidden = min(transfer_latency, scheduling_latency)
    ratio  = hidden / transfer_latency
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

from repro.core.costmodel import CostModel
from repro.core.events import EventLoop
from repro.core.mm_store import MMStore


@dataclass
class PrefetchRecord:
    request_id: int
    transfer_latency: float
    scheduling_latency: float
    recomputed: bool = False
    evicted_in_flight: bool = False

    @property
    def hidden(self) -> float:
        return min(self.transfer_latency, self.scheduling_latency)

    @property
    def exposed(self) -> float:
        return max(0.0, self.transfer_latency - self.scheduling_latency)

    @property
    def overlap_ratio(self) -> float:
        if self.transfer_latency <= 0:
            return 1.0
        return self.hidden / self.transfer_latency


class EPPrefetcher:
    """Event-driven E->P feature mover; one per Prefill instance."""

    def __init__(self, loop: EventLoop, store: MMStore, cost: CostModel,
                 *, async_mode: bool = True, pin: bool = True):
        self.loop = loop
        self.store = store
        self.cost = cost
        self.async_mode = async_mode
        # pin=True holds a refcount on the feature between announce and
        # fire so an interleaved eviction cannot vanish it mid-prefetch;
        # pin=False falls back to the fire-time re-check + recompute arm.
        self.pin = pin
        self.records: List[PrefetchRecord] = []
        self.inflight_evictions = 0

    def notify(self, request_id: int, key: str, n_tokens: int,
               on_ready: Callable[[bool], None],
               scheduling_latency_hint: float = 0.0) -> float:
        """Encode-side: announce feature availability by hash.

        on_ready(recomputed) fires when the Prefill instance can start
        consuming the feature. Returns the time the ENCODE instance stays
        blocked: in the synchronous baseline the feature is pushed E->P on
        E's stream (stretching E's effective service time and compounding
        queueing); in async mode only the hash is sent and E is free
        immediately while P's listener pulls from the MM Store in the
        background.
        """
        nbytes = self.cost.feature_bytes(n_tokens)
        transfer = self.cost.feature_transfer_time(nbytes)
        # dispatch (scheduler tick + batch formation + local cache write)
        # happens regardless of mode; the async transfer hides behind it
        # and behind any Prefill queue backlog.
        sched = max(self.cost.dispatch_latency(nbytes),
                    scheduling_latency_hint)
        found = self.store.get(key, record=False) is not None
        pinned = bool(self.pin and found and self.store.pin(key))
        recompute = 0.0
        if not found:
            # fault-tolerant recomputation on the Prefill instance
            recompute = self.cost.encode_time(n_tokens)
            transfer = 0.0
        rec = PrefetchRecord(request_id, transfer, sched,
                             recomputed=not found)
        self.records.append(rec)
        if self.async_mode:
            # transfer overlaps the dispatch path: only the EXPOSED part
            # delays Prefill, and E is not blocked at all
            delay = max(sched, transfer) + recompute
            e_block = 0.0
        else:
            # synchronous baseline: the feature push is serial with
            # dispatch AND sits on the Encode instance's stream
            delay = sched + transfer + recompute
            e_block = transfer

        def _fire() -> None:
            # Presence was checked at ANNOUNCE time but on_ready fires
            # `delay` later — an eviction in that window would hand
            # Prefill a vanished entry. Release any pin, then re-check:
            # a gap here routes through the same recompute arm a store
            # miss does (charged as extra delay before on_ready).
            if pinned:
                self.store.unpin(key)
            if found and not self.store.contains(key):
                rec.evicted_in_flight = True
                rec.recomputed = True
                self.inflight_evictions += 1
                self.loop.after(self.cost.encode_time(n_tokens),
                                lambda: on_ready(True))
                return
            on_ready(rec.recomputed)

        self.loop.after(delay, _fire)
        return e_block

    # -- metrics ---------------------------------------------------------------
    @property
    def mean_overlap_ratio(self) -> float:
        xfers = [r for r in self.records if not r.recomputed]
        if not xfers:
            return 1.0
        return sum(r.overlap_ratio for r in xfers) / len(xfers)

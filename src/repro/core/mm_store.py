"""MM Store — the shared multimodal feature cache pool (paper §3.2).

Content-hash keyed: key = hash(multimodal input), value = encoded feature
tensor (or, in simulation, its metadata). Supports cross-request reuse
(dedup), LRU eviction under a byte budget, and fault injection so the
fault-tolerant recomputation path is testable.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from repro.core.faults import SITE_STORE_FETCH, FaultInjector, StoreMiss


@dataclass
class StoreStats:
    puts: int = 0
    hits: int = 0
    misses: int = 0
    dedup_puts: int = 0          # put of an already-present key
    evictions: int = 0
    rejected_puts: int = 0       # entry alone exceeds capacity
    faults_injected: int = 0
    bytes_stored: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class MMStore:
    """Hash-keyed feature pool with LRU eviction."""

    def __init__(self, capacity_bytes: Optional[int] = None,
                 injector: Optional[FaultInjector] = None):
        self.capacity = capacity_bytes
        self._data: "collections.OrderedDict[str, Tuple[Any, int]]" = \
            collections.OrderedDict()
        self.stats = StoreStats()
        # pinned entries (refcounted) are exempt from eviction: the E->P
        # prefetcher pins a feature between announce and fire so the
        # Prefill consumer never races an interleaved eviction.
        self._pins: Dict[str, int] = {}
        # All fault decisions route through the (possibly shared) fault
        # plane; a private injector with an empty plan means "no faults"
        # until someone arms one via inject_fault.
        self.injector = injector if injector is not None else FaultInjector()

    # -- core API -------------------------------------------------------------
    def put(self, key: str, value: Any, nbytes: int) -> None:
        if key in self._data:
            # dedup put of a known key: the key is content-addressed so
            # the VALUE is semantically identical, but a recompute may
            # re-put under the same hash with a different representation
            # (or corrected size) — adopt the new tuple and reconcile
            # byte accounting instead of silently keeping the stale one.
            self.stats.dedup_puts += 1
            old_nb = self._data[key][1]
            self._data[key] = (value, nbytes)
            self.stats.bytes_stored += nbytes - old_nb
            self._data.move_to_end(key)
            self._evict()
            return
        if self.capacity is not None and nbytes > self.capacity:
            # an entry that alone exceeds capacity can never fit the
            # budget: admitting it would pin ``bytes_stored`` above
            # ``capacity`` forever (the old `len > 1` eviction guard did
            # exactly that). Reject the put outright — the caller holds
            # the value it just computed, so nothing is lost.
            self.stats.rejected_puts += 1
            return
        self.stats.puts += 1
        self._data[key] = (value, nbytes)
        self.stats.bytes_stored += nbytes
        self._evict()

    def get(self, key: str, record: bool = True,
            attempt: int = 0) -> Optional[Any]:
        """record=False: internal fetch (e.g. the P-side prefetcher pulling
        a feature the E stage just produced) — served but not counted in
        the hit/miss statistics, which track cross-request dedup.
        ``attempt`` keys the injector's deterministic draw: a *retry* of
        the same fetch re-draws, so transient faults heal under the
        store-fetch retry arm."""
        if self.injector.should_fail(SITE_STORE_FETCH, key=key,
                                     attempt=attempt):
            # injected fault: behaves like a lost entry (paper §3.2 FT path)
            self.stats.faults_injected += 1
            if record:
                self.stats.misses += 1
            return None
        if key in self._data:
            if record:
                self.stats.hits += 1
            self._data.move_to_end(key)
            return self._data[key][0]
        if record:
            self.stats.misses += 1
        return None

    def contains(self, key: str) -> bool:
        return key in self._data

    def nbytes(self, key: str) -> int:
        return self._data[key][1] if key in self._data else 0

    def resident_bytes(self) -> int:
        """Ground-truth sum of resident entry sizes (audits: must always
        equal ``stats.bytes_stored``)."""
        return sum(nb for _, nb in self._data.values())

    # -- pinning --------------------------------------------------------------
    def _pinned(self, key: str) -> bool:
        return self._pins.get(key, 0) > 0

    def pin(self, key: str) -> bool:
        """Refcounted eviction exemption for an in-flight consumer (the
        E->P prefetcher between announce and fire). Returns False when
        the key is not resident (nothing to pin)."""
        if key not in self._data:
            return False
        self._pins[key] = self._pins.get(key, 0) + 1
        return True

    def unpin(self, key: str) -> None:
        n = self._pins.get(key, 0)
        if n <= 1:
            self._pins.pop(key, None)
        else:
            self._pins[key] = n - 1
        # release may leave the store above budget (pins can hold it
        # there); reconverge now that the entry is evictable again
        self._evict()

    def _evict(self) -> None:
        if self.capacity is None:
            return
        while self.stats.bytes_stored > self.capacity:
            # LRU order, skipping pinned entries. A single oversized
            # entry is evicted too (the old `len > 1` guard kept it
            # forever with bytes_stored > capacity never reconverging).
            victim = next((k for k in self._data if not self._pinned(k)),
                          None)
            if victim is None:
                return                     # everything pinned: hold over
            _, nb = self._data.pop(victim)
            self.stats.bytes_stored -= nb
            self.stats.evictions += 1

    def fetch(self, key: str, attempt: int = 0) -> Any:
        """Typed fetch: like ``get`` but a lost/faulted/absent entry
        raises :class:`StoreMiss` (carrying the key and attempt number)
        instead of returning None — what the retry-then-recompute arm
        catches."""
        val = self.get(key, attempt=attempt)
        if val is None:
            raise StoreMiss(key, attempts=attempt + 1)
        return val

    # -- fault injection --------------------------------------------------------
    def inject_fault(self, key: str) -> None:
        """Legacy one-shot hook, kept as a shim: arms exactly one
        store-fetch fault for ``key`` on the shared injector."""
        self.injector.arm(SITE_STORE_FETCH, key=key)

    def __len__(self) -> int:
        return len(self._data)

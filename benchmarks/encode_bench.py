"""Encode-stage E->P hand-off benchmark: async prefetch vs sync push
vs encode-inline.

Two halves, both deterministic:

1. REAL cluster (llava reduced): one multimodal request through each
   overlap arm. Greedy output must be BIT-IDENTICAL across all three
   arms and the monolithic engine (the arms differ only in modeled
   accounting), the per-request transfer component must order
   inline < async <= sync, a same-image/longer-prompt follow-up must
   skip the encode forward outright via the (mm-hash, token-run)
   prefix key, and the traced run must satisfy the components-sum-
   to-e2e ledger invariant.

2. MODELED sweep (openpangu-7b-vl cost model): single-request TTFT at
   the paper's Table 3 resolutions under each arm. Async must beat the
   synchronous push at >= 2 resolutions (the transfer hides under
   dispatch + the pre-image text prefill; only the feature-arrival
   barrier at the first image position is exposed).

Emits a BENCH_encode.json snapshot next to the repo root so the
E->P overlap trajectory is recorded per PR.
"""
from __future__ import annotations

import json
import os
from typing import List

# async must beat sync on modeled TTFT at at least this many of the
# paper's Table 3 resolutions
MIN_ASYNC_WINS = 2


def bench_encode() -> List[str]:
    import jax
    from repro.configs import get_config
    from repro.core.cluster import EPDCluster
    from repro.core.costmodel import CostModel
    from repro.core.telemetry import Tracer
    from repro.models import frontend as FE
    from repro.models.model import init_params
    from repro.serving.engine import Engine
    from repro.serving.request import Request

    rows = ["encode,value,derived"]
    snap = {"config": {"real_model": "llava-next-mistral-7b (reduced)",
                       "modeled_model": "openpangu-7b-vl",
                       "text_tokens": 256, "mm_pos": 64},
            "cluster": {}, "resolutions": []}

    # ---- REAL cluster: three arms, bit-identical, ledger-clean ----
    cfg = get_config("llava-next-mistral-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = list(range(5, 15))

    outs, arms = {}, {}
    for arm in ("async", "sync", "inline"):
        tracer = Tracer(enabled=True)
        cl = EPDCluster(cfg, params, max_batch=2, max_len=96, paged=True,
                        page_size=8, prefix_cache=True, ep_overlap=arm,
                        tracer=tracer)
        r = Request(prompt_tokens=list(prompt), max_new_tokens=5,
                    mm_payload=b"bench-img", mm_tokens=8, mm_pos=4)
        cl.submit(r)
        cl.run_until_done()
        # same image + longer prompt: the (mm-hash, token-run) prefix
        # key covers the whole image run -> encode skipped outright
        r2 = Request(prompt_tokens=list(prompt) + [77, 78],
                     max_new_tokens=5, mm_payload=b"bench-img",
                     mm_tokens=8, mm_pos=4)
        cl.submit(r2)
        cl.run_until_done()
        assert cl.report.encode_skips == 1, \
            f"{arm}: cache-hit rerun must skip the encode forward"
        assert cl.store.stats.puts == 1
        tracer.assert_balanced()
        cl.acc.assert_all_closed()
        cl.acc.check_all(tol=0.01)
        cl.prefill_engine.assert_no_page_leaks()
        cl.decode_engine.assert_no_page_leaks()
        att = cl.attribution()
        row = att["requests"][0]
        outs[arm] = (list(r.output_tokens), list(r2.output_tokens))
        arms[arm] = {
            "transfer_ms": row["components_ms"]["transfer"],
            "encode_skips": cl.report.encode_skips,
            "overlap_ratio": round(
                cl.metrics.value("ep_overlap_ratio"), 4),
            "mean_components_ms": att["mean_components_ms"],
        }

    mono = Engine(cfg, params, max_batch=2, max_len=96)
    rm = Request(prompt_tokens=list(prompt), max_new_tokens=5,
                 mm_payload=b"bench-img", mm_tokens=8, mm_pos=4)
    mono.run_request(rm)
    assert outs["async"] == outs["sync"] == outs["inline"], \
        "overlap arms must be bit-identical"
    assert outs["async"][0] == list(rm.output_tokens), \
        "disaggregated encode must match the monolithic engine"
    xi, xa, xs = (arms[a]["transfer_ms"]
                  for a in ("inline", "async", "sync"))
    assert xi < xa <= xs, \
        f"E->P exposure must order inline<async<=sync ({xi},{xa},{xs})"

    snap["cluster"] = {"arms": arms, "bit_identical": True,
                       "monolithic_match": True}
    rows.append(
        f"cluster_arms,bit_identical,"
        f"transfer_ms_inline_{xi}_async_{xa}_sync_{xs}")
    rows.append(
        f"cluster_prefix_reuse,encode_skipped,"
        f"1_skip_1_put_overlap_{arms['async']['overlap_ratio']}")

    # ---- MODELED sweep: Table 3 resolutions, single-request TTFT ----
    model = get_config("openpangu-7b-vl")
    cost = CostModel(model)
    text, mm_pos = 256, 64
    wins = 0
    for res, n_mm in sorted(FE.PAPER_RESOLUTION_TOKENS.items(),
                            key=lambda kv: kv[1]):
        total = text + n_mm
        enc = cost.encode_time(n_mm)
        pf = cost.prefill_time(total)
        nbytes = cost.feature_bytes(n_mm)
        disp = cost.dispatch_latency(nbytes)
        xfer = cost.feature_transfer_time(nbytes)
        # the pre-image text chunk prefills while the feature is in
        # flight; the barrier is only at the first image position
        pre = cost.chunk_prefill_times(total, [mm_pos, total - mm_pos])[0]
        ttft = {
            "inline": enc + pf,
            "sync": enc + disp + xfer + pf,
            "async": enc + disp + max(0.0, xfer - disp - pre) + pf,
        }
        hidden = min(xfer, disp + pre)
        win = ttft["async"] < ttft["sync"]
        wins += win
        snap["resolutions"].append({
            "resolution": f"{res[0]}x{res[1]}", "mm_tokens": n_mm,
            "feature_mb": round(nbytes / 2**20, 2),
            "ttft_ms": {k: round(v * 1e3, 3) for k, v in ttft.items()},
            "transfer_hidden_ms": round(hidden * 1e3, 3),
            "overlap_ratio": round(hidden / xfer, 4) if xfer else 1.0,
            "async_beats_sync": bool(win),
        })
        rows.append(
            f"modeled_{res[0]}x{res[1]},{n_mm}_mm_tokens,"
            f"ttft_ms_async_{ttft['async'] * 1e3:.2f}_"
            f"sync_{ttft['sync'] * 1e3:.2f}_"
            f"inline_{ttft['inline'] * 1e3:.2f}")
    assert wins >= MIN_ASYNC_WINS, \
        f"async must beat sync at >= {MIN_ASYNC_WINS} resolutions " \
        f"(got {wins})"
    snap["config"]["async_wins"] = wins
    rows.append(f"modeled_sweep,async_wins,"
                f"{wins}_of_{len(FE.PAPER_RESOLUTION_TOKENS)}_resolutions")

    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_encode.json")
    with open(os.path.abspath(out), "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows


if __name__ == "__main__":
    for row in bench_encode():
        print(row)

"""Hypothesis chaos suite for the fault plane: injector replay
determinism, transfer-recovery payload conservation, swap-tier loss
under arbitrary pool interleavings, engine-level swap-loss recovery
during preempt/resume chaos, and full-cluster runs under random
per-site fault rates — through every arm, page refcounts and swap
handles must balance and no request may be silently dropped. Honors
HYPOTHESIS_PROFILE=ci (conftest)."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from conftest import hyp_max_examples
from repro.core import kv_transfer as kt
from repro.core.faults import (DEFAULT_RETRY, NO_RETRY, SITE_DECODE_CRASH,
                               SITE_SWAP_IN, SITE_TRANSFER_HANDSHAKE,
                               SITE_TRANSFER_WIRE, SITES, FaultInjector,
                               FaultPlan, RetryPolicy, SwapLost,
                               TransferError)
from repro.serving.kv_pool import PagePool, PoolExhausted
from repro.serving.request import Request

SITE_LIST = sorted(SITES)


# ---------------------------------------------------------------------------
# injector: pure-function determinism under arbitrary plans
# ---------------------------------------------------------------------------

@settings(max_examples=hyp_max_examples(80), deadline=None)
@given(st.integers(0, 2**31), st.floats(0.0, 1.0),
       st.lists(st.tuples(st.integers(0, len(SITE_LIST) - 1),
                          st.integers(0, 5), st.integers(0, 3)),
                min_size=1, max_size=60))
def test_injector_is_pure_function_of_plan(seed, rate, calls):
    """Two injectors with the same plan agree on every decision, in any
    call order; fired count == number of True decisions; rate 0 never
    fires and rate 1 always fires (modulo the cap)."""
    plan = FaultPlan(seed=seed, rates={s: rate for s in SITE_LIST})
    a, b = FaultInjector(plan), FaultInjector(plan)
    seq = [(SITE_LIST[i], k, at) for i, k, at in calls]
    ra = [a.should_fail(s, key=k, attempt=at) for s, k, at in seq]
    rb = [b.should_fail(s, key=k, attempt=at)
          for s, k, at in reversed(seq)]
    assert ra == list(reversed(rb))
    assert a.n_fired() == sum(ra)
    if rate == 0.0:
        assert not any(ra)
    if rate == 1.0:
        assert all(ra)


# ---------------------------------------------------------------------------
# transfer recovery: payload conservation for every (plan, rates) draw
# ---------------------------------------------------------------------------

@settings(max_examples=hyp_max_examples(60), deadline=None)
@given(st.integers(0, 2**31), st.floats(0.0, 0.9), st.floats(0.0, 0.9),
       st.integers(1, 16), st.integers(0, 4), st.booleans())
def test_recover_plan_conserves_payload_or_raises_typed(
        seed, hs_rate, wire_rate, n_layers, group_size, replan):
    """For arbitrary fault rates, recover_plan either raises
    TransferError or returns a plan that delivers every source group
    exactly once, never touches the compute timeline, and only ever
    inflates latency — with the recovery record internally consistent."""
    p = kt.plan("grouped", n_layers=n_layers, bytes_per_layer=1e6,
                per_layer_compute=1e-3, handshake=1e-3, link_bw=1e9,
                group_size=group_size)
    inj = FaultInjector(FaultPlan(seed=seed, rates={
        SITE_TRANSFER_HANDSHAKE: hs_rate, SITE_TRANSFER_WIRE: wire_rate}))
    policy = RetryPolicy(max_attempts=3, backoff_base=1e-4, seed=seed)
    try:
        out, rec = kt.recover_plan(p, injector=inj, policy=policy,
                                   handshake=1e-3, link_bw=1e9,
                                   key=seed, replan=replan)
    except TransferError as e:
        assert e.site in (SITE_TRANSFER_HANDSHAKE, SITE_TRANSFER_WIRE)
        assert isinstance(e, RuntimeError)
        return
    assert sorted(g.start for g in out.groups) == \
        sorted(g.start for g in p.groups)
    assert abs(sum(g.nbytes for g in out.groups)
               - sum(g.nbytes for g in p.groups)) < 1e-6
    assert out.prefill_end == p.prefill_end
    assert out.kv_latency >= p.kv_latency
    assert out.exposed_latency >= p.exposed_latency
    assert rec.retries >= rec.faults - rec.replanned_groups * 0
    assert rec.retry_time >= 0.0
    if rec.faults == 0:
        assert out is p
    # every delivered group lands no earlier than physics allows
    for g in out.groups:
        assert g.t_done >= g.t_ready


# ---------------------------------------------------------------------------
# swap tier: SwapLost under arbitrary pool interleavings
# ---------------------------------------------------------------------------

@settings(max_examples=hyp_max_examples(50), deadline=None)
@given(st.integers(0, 2**31), st.floats(0.0, 0.8),
       st.lists(st.tuples(st.sampled_from(["alloc", "free", "out", "in"]),
                          st.integers(0, 7), st.integers(1, 6)),
                min_size=1, max_size=40))
def test_pool_swap_loss_keeps_audit_balanced(seed, rate, ops):
    """Under random swap-in losses, a lost handle is consumed (no
    device pages allocated, host entry dropped) and the allocator /
    swap audit balances after every operation — no arm leaks."""
    inj = FaultInjector(FaultPlan(seed=seed, rates={SITE_SWAP_IN: rate}))
    pool = PagePool(33, 4, injector=inj)
    live, swapped, losses = {}, {}, 0
    rid = 0
    for op, pick, n in ops:
        if op == "alloc" and pool.n_free >= n:
            live[rid] = pool.alloc(n)
            rid += 1
        elif op == "free" and live:
            k = sorted(live)[pick % len(live)]
            pool.free(live.pop(k))
        elif op == "out" and live:
            k = sorted(live)[pick % len(live)]
            ids = live.pop(k)
            swapped[k] = pool.swap_out(ids, data=len(ids))
        elif op == "in" and swapped:
            k = sorted(swapped)[pick % len(swapped)]
            h = swapped[k]
            try:
                ids, data = pool.swap_in(h)
            except SwapLost as e:
                assert e.handle_id == h.handle_id
                assert e.n_pages == h.n_pages
                del swapped[k]              # consumed: must not be reused
                losses += 1
                with pytest.raises(ValueError):
                    pool.swap_in(h)
            except PoolExhausted:
                pass                        # handle stays valid
            else:
                assert data == len(ids) == h.n_pages
                del swapped[k]
                live[k] = ids
        pool.assert_balanced(live.values(),
                             swap_handles=swapped.values())
    assert pool.swap_lost_total == losses


# ---------------------------------------------------------------------------
# REAL engine: preempt/resume chaos with swap-loss recovery
# ---------------------------------------------------------------------------

_ENGINE = None


def _chaos_engine():
    global _ENGINE
    if _ENGINE is None:
        import jax
        from repro.configs import get_config
        from repro.models.model import init_params
        from repro.serving.engine import Engine
        cfg = get_config("smollm-135m").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        # prefix_cache gives the engine its suffix-prefill path, which
        # the swap-loss arm reuses for the §re-fault recompute
        _ENGINE = Engine(cfg, params, max_batch=2, max_len=32, paged=True,
                         page_size=4, prefix_cache=True, preemption=True,
                         n_pool_pages=24, faults=FaultInjector())
    return _ENGINE


def _reset(eng):
    from repro.serving.prefix_cache import PrefixCache
    for i, r in enumerate(eng.slots):
        if r is not None:
            eng.slots[i] = None
            eng._release_slot(i)
    for pr in eng.preempted:
        if pr.handle is not None:
            eng.pool.swap_free(pr.handle)
    eng.preempted.clear()
    eng._resume_marks.clear()
    eng.lost.clear()
    eng.prefix_cache.evict(eng.pool.n_pages)
    eng.prefix_cache = PrefixCache(eng.page_size, eng.pool)
    assert eng.pool.n_used == 0, "reset must drain the pool"
    assert eng.pool.n_swapped_pages == 0, "reset must drain the swap"


@settings(max_examples=hyp_max_examples(20), deadline=None)
@given(st.integers(0, 2**31), st.floats(0.0, 0.6),
       st.lists(st.tuples(
           st.sampled_from(["prefill", "insert", "decode", "preempt",
                            "resume"]),
           st.integers(0, 3), st.integers(1, 12)),
           min_size=1, max_size=12))
def test_engine_chaos_swap_loss_never_leaks_or_drops(seed, rate, ops):
    """Arbitrary interleavings of prefill / insert / decode / preempt /
    resume with a random swap-in loss rate: every SwapLost is absorbed
    by the suffix-recompute arm (or surfaced in eng.lost), the page /
    swap audit balances after every op, and at drain time every request
    that entered a slot is accounted live, finished, or lost — never
    silently gone."""
    eng = _chaos_engine()
    _reset(eng)
    eng.pool.injector = FaultInjector(
        FaultPlan(seed=seed, rates={SITE_SWAP_IN: rate}))
    pending, entered, finished = [], [], []
    try:
        for op, pick, ln in ops:
            if op == "prefill":
                prompt = [pick * 500 + j // 2 for j in range(ln)]
                r = Request(prompt_tokens=prompt, max_new_tokens=4)
                try:
                    f, p = eng.prefill_request(r)
                    pending.append((r, f, p))
                except RuntimeError:
                    pass                    # pool exhausted: atomic unwind
            elif op == "insert" and pending:
                r, f, p = pending.pop(pick % len(pending))
                try:
                    eng.insert(r, p, f)
                    entered.append(r)
                except RuntimeError:
                    pending.append((r, f, p))
            elif op == "decode" and eng.n_active:
                try:
                    for r, tok, done in eng.decode_step():
                        if done:
                            finished.append(r)
                except RuntimeError:
                    pass
            elif op == "preempt":
                active = [i for i, r in enumerate(eng.slots)
                          if r is not None]
                if active:
                    eng.preempt_slot(active[pick % len(active)])
            elif op == "resume":
                eng.try_resume()            # may take the SwapLost arm
            eng.assert_no_page_leaks(
                extra_holders=[p.page_ids for _, _, p in pending])
        # no silent drops: everything that entered a slot is live,
        # parked, finished, or surfaced as lost
        in_slots = [r for r in eng.slots if r is not None]
        parked = [pr.req for pr in eng.preempted]
        for r in entered:
            assert (any(r is x for x in in_slots)
                    or any(r is x for x in parked)
                    or any(r is x for x in finished)
                    or any(r is x for x in eng.lost)), \
                "request silently dropped"
        assert all(r.killed for r in eng.lost)
    finally:
        for _, _, p in pending:
            eng.release_payload(p)
        _reset(eng)
        eng.pool.injector = FaultInjector()


# ---------------------------------------------------------------------------
# REAL cluster: end-to-end chaos accounting
# ---------------------------------------------------------------------------

_CLUSTER_DEPS = None


def _cluster_deps():
    global _CLUSTER_DEPS
    if _CLUSTER_DEPS is None:
        import jax
        from repro.configs import get_config
        from repro.models.model import init_params
        cfg = get_config("smollm-135m").reduced()
        _CLUSTER_DEPS = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
    return _CLUSTER_DEPS


@settings(max_examples=hyp_max_examples(8), deadline=None)
@given(st.integers(0, 2**31), st.floats(0.0, 0.15),
       st.integers(0, 6), st.booleans())
def test_cluster_chaos_accounting_closes(seed, wire_rate, crash_step,
                                         recovery):
    """A 2-decode-instance cluster under random wire-fault rates plus
    one armed mid-run crash: with recovery every completion is exact-
    length and losses are surfaced (never silent) — done + lost ==
    submitted — and the surviving engines end leak-free with the retry
    time accounted."""
    from repro.core.cluster import EPDCluster
    from repro.core.faults import ArmedFault
    cfg, params = _cluster_deps()
    plan = FaultPlan(
        seed=seed,
        rates={SITE_TRANSFER_WIRE: wire_rate},
        armed=[ArmedFault(SITE_DECODE_CRASH, key=(0, crash_step))])
    cl = EPDCluster(cfg, params, max_batch=2, max_len=64, paged=True,
                    page_size=8, prefix_cache=True, n_decode=2,
                    faults=plan, recovery=recovery)
    reqs = [Request(prompt_tokens=list(range(3 + i, 19 + i)),
                    max_new_tokens=6) for i in range(3)]
    for r in reqs:
        cl.submit(r)
    done = cl.run_until_done(max_steps=400)
    # accounting closes: every submitted request is done or lost
    assert len(done) + len(cl.report.lost) == len(reqs)
    assert all(r.killed for r in cl.report.lost)
    for r in done:
        assert len(r.output_tokens) == r.max_new_tokens
    if recovery:
        assert not cl.report.lost       # every arm healed
    assert cl.report.retry_time_total >= 0.0
    if cl.report.transfer_retries == 0 and cl.report.store_retries == 0:
        assert cl.report.retry_time_total == 0.0
    for i in cl.live_decode_indices():
        cl.decode_engines[i].assert_no_page_leaks()
    cl.prefill_engine.assert_no_page_leaks()

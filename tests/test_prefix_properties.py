"""Hypothesis property tests for the radix-tree prefix cache, the page
pool's host swap space, and the (chunked, preemptible) paged engine:
ref-count conservation, branch integrity, swap-handle balance, and
match/page agreement under arbitrary interleavings of (chunked)
prefills, inserts, decode steps, early-EOS releases, evictions, and
preempt/resume cycles. Honors HYPOTHESIS_PROFILE=ci (conftest)."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from conftest import hyp_max_examples
from repro.serving.kv_pool import PagePool, PoolExhausted
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request


# ---------------------------------------------------------------------------
# hypothesis: ref-count + branch-integrity + swap-handle invariants
# ---------------------------------------------------------------------------


@settings(max_examples=hyp_max_examples(60), deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["insert", "release", "evict",
                                           "preempt", "resume"]),
                          st.integers(0, 7), st.integers(1, 20)),
                min_size=1, max_size=40),
       st.integers(2, 8))
def test_tree_refcount_invariant(ops, page):
    """Total refs per page == retaining requests + tree retentions, and
    the host swap store == outstanding preempted handles, under
    arbitrary interleavings of insert / release / evict / preempt
    (swap_out) / resume (swap_in); inserted sequences stay matchable
    unless evicted; unrelated branches survive."""
    pool = PagePool(257, page_size=page)
    cache = PrefixCache(page, pool)
    live = {}                                     # rid -> (tokens, ids)
    swapped = {}                                  # rid -> (tokens, handle)
    rid = 0
    for op, fam, ln in ops:
        if op == "insert" and pool.n_free >= pool.pages_for(ln):
            # family gives shared prefixes; ln the total length
            tokens = [fam * 1000 + j // 3 for j in range(ln)]
            ids = pool.alloc(pool.pages_for(ln))
            cache.insert(tokens, ids)
            live[rid] = (tokens, ids)
            rid += 1
        elif op == "release" and live:
            k = sorted(live)[fam % len(live)]
            _, ids = live.pop(k)
            pool.free(ids)
        elif op == "evict":
            cache.evict(ln)
        elif op == "preempt" and live:
            # the request's holdership moves to a swap handle: shared
            # pages survive on device under the tree's refs, private
            # ones return to the free list — either way the audit must
            # keep balancing
            k = sorted(live)[fam % len(live)]
            tokens, ids = live.pop(k)
            swapped[k] = (tokens, pool.swap_out(ids, data=len(ids)))
        elif op == "resume" and swapped:
            k = sorted(swapped)[fam % len(swapped)]
            tokens, h = swapped[k]
            try:
                ids, data = pool.swap_in(h)
            except PoolExhausted:
                pass                     # handle stays valid and audited
            else:
                assert data == len(ids) == h.n_pages
                del swapped[k]
                live[k] = (tokens, ids)
        # invariant: allocator state == request holders + tree
        # retentions; swap store == outstanding handles
        pool.assert_balanced(
            [ids for _, ids in live.values()] + [cache.retained_pages()],
            swap_handles=[h for _, h in swapped.values()])
    # match structure agrees with the refs it takes: one page per full
    # matched page, a CoW source iff the match ends inside a page (same-
    # family sequences share prefixes, so a match may run past one
    # request's own full pages into a longer relative's retention)
    for tokens, _ in live.values():
        m = cache.match_and_ref(tokens)
        assert m.n_tokens <= len(tokens)
        assert m.n_full_pages == m.n_tokens // page
        assert (m.cow_src is None) == (m.n_tokens % page == 0)
        pool.unref(m.page_ids)
        if m.cow_src is not None:
            pool.unref([m.cow_src])


@settings(max_examples=hyp_max_examples(30), deadline=None)
@given(st.lists(st.lists(st.integers(0, 3), min_size=1, max_size=24),
                min_size=2, max_size=8))
def test_tree_match_is_true_prefix(seqs):
    """match_len never exceeds the true longest common prefix with some
    inserted sequence (no cross-branch corruption)."""
    page = 4
    cache = PrefixCache(page)
    inserted = []
    for s in seqs:
        cache.insert(s)
        inserted.append(list(s))
    for s in seqs:
        probe = list(s) + [99]
        n = cache.match_len(probe)
        best = 0
        for t in inserted:
            full = (len(t) // page) * page
            lcp = 0
            while (lcp < min(len(probe), len(t)) and probe[lcp] == t[lcp]):
                lcp += 1
            best = max(best, min(lcp, full))
        assert n == best


# ---------------------------------------------------------------------------
# deterministic regression: the double-preempt starvation guard
# ---------------------------------------------------------------------------


def test_starvation_guard_pick_semantics():
    from repro.core.scheduler import (VictimCandidate,
                                      pick_preemption_victim)
    fresh = VictimCandidate(slot=0, pages_lost=9)
    resumed_stuck = VictimCandidate(slot=1, pages_lost=1,
                                    made_progress=False, preempt_count=1)
    resumed_ok = VictimCandidate(slot=2, pages_lost=5,
                                 made_progress=True, preempt_count=3)
    # the cheapest victim is guarded: pick the cheapest ELIGIBLE one
    v = pick_preemption_victim([fresh, resumed_stuck, resumed_ok])
    assert v.slot == 2
    # priority dominates page cost
    hi = VictimCandidate(slot=3, pages_lost=1, priority=1)
    assert pick_preemption_victim([fresh, hi]).slot == 0
    # everyone guarded: deny (None), never thrash
    assert pick_preemption_victim([resumed_stuck]) is None
    # a never-preempted request that hasn't "progressed" is still fair
    # game (made_progress only gates RE-preemption)
    new_stale = VictimCandidate(slot=4, pages_lost=2,
                                made_progress=False, preempt_count=0)
    assert pick_preemption_victim([new_stale]).slot == 4


def test_starvation_guard_engine_regression(monkeypatch):
    """A request resumed this step (no token since) is not preempted a
    second time: the engine must pick the other active slot, and deny
    when the resumed one is the only candidate."""
    import jax
    from repro.configs import get_config
    from repro.models.model import init_params
    from repro.serving.engine import Engine
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_len=32, paged=True,
                 page_size=4, preemption=True, n_pool_pages=32)
    a = Request(prompt_tokens=list(range(2, 10)), max_new_tokens=12)
    b = Request(prompt_tokens=list(range(20, 28)), max_new_tokens=12)
    for r in (a, b):
        f, p = eng.prefill_request(r)
        eng.insert(r, p, f)
    slot_a = next(i for i, s in enumerate(eng.slots) if s is a)
    eng.preempt_slot(slot_a)
    assert eng.try_resume() == 1                   # a is back, no token yet
    assert a.n_preempts == 1
    assert eng._preempt_one()                      # guard: must pick b
    assert any(s is a for s in eng.slots)
    assert b.n_preempts == 1
    assert eng.try_resume() == 1                   # b back, also no token
    # both active, both resumed-without-progress: deny outright
    assert not eng._preempt_one()
    assert eng.preempt_count == 2
    eng.decode_step()                              # one token of progress
    assert eng._preempt_one()                      # guard lifts
    for pr in list(eng.preempted):
        if pr.handle is not None:
            eng.pool.swap_free(pr.handle)
    eng.preempted.clear()
    eng.assert_no_page_leaks()


# ---------------------------------------------------------------------------
# hypothesis: chunked+preemptible engine — refcount conservation with
# REAL compute
# ---------------------------------------------------------------------------

# one engine shared across examples (jit caches amortized); every example
# starts from a full reset so examples stay independent / reproducible
_ENGINE = None


def _chunked_engine():
    global _ENGINE
    if _ENGINE is None:
        import jax
        from repro.configs import get_config
        from repro.models.model import init_params
        from repro.serving.engine import Engine
        cfg = get_config("smollm-135m").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        # deliberately tight pool (19 usable pages) so interleavings hit
        # exhaustion, eviction-under-pressure, the chunk-loop unwind,
        # and organic decode-growth preemption
        _ENGINE = Engine(cfg, params, max_batch=2, max_len=32, paged=True,
                         page_size=4, prefix_cache=True,
                         chunked_prefill=True, prefill_chunk=8,
                         preemption=True, n_pool_pages=20)
    return _ENGINE


def _reset(eng):
    for i, r in enumerate(eng.slots):
        if r is not None:
            eng.slots[i] = None
            eng._release_slot(i)
    for pr in eng.preempted:
        if pr.handle is not None:
            eng.pool.swap_free(pr.handle)
    eng.preempted.clear()
    eng._resume_marks.clear()
    eng.prefix_cache.evict(eng.pool.n_pages)
    eng.prefix_cache = PrefixCache(eng.page_size, eng.pool)
    assert eng.pool.n_used == 0, "reset must drain the pool"
    assert eng.pool.n_swapped_pages == 0, "reset must drain the swap"


@settings(max_examples=hyp_max_examples(25), deadline=None)
@given(st.lists(st.tuples(
    st.sampled_from(["prefill", "insert", "decode", "eos", "release",
                     "evict", "preempt", "resume"]),
    st.integers(0, 3), st.integers(1, 16)), min_size=1, max_size=14))
def test_chunked_engine_refcount_conservation(ops):
    """Pool accounting stays exact under arbitrary interleavings of
    CHUNKED prefills (family-shared prefixes: cache hits, CoW), decode
    steps (page growth, which may organically preempt), early-EOS slot
    releases, payload releases, prefix-cache evictions, and explicit
    preempt/resume cycles — including pool-exhaustion unwinds. The
    audit covers device pages AND outstanding swap handles."""
    eng = _chunked_engine()
    _reset(eng)
    pending = []                            # prefilled, not yet inserted
    for op, fam, ln in ops:
        if op == "prefill":
            # family gives shared prefixes (hits + intra-page divergence)
            prompt = [fam * 1000 + j // 2 for j in range(ln)]
            r = Request(prompt_tokens=prompt, max_new_tokens=4)
            try:
                f, p = eng.prefill_request(r)
                pending.append((r, f, p))
            except RuntimeError:
                pass                        # exhausted: unwind, no leaks
        elif op == "insert" and pending:
            r, f, p = pending.pop(fam % len(pending))
            try:
                eng.insert(r, p, f)
            except RuntimeError:            # no free slot: stays retryable
                pending.append((r, f, p))
        elif op == "decode" and eng.n_active:
            try:
                eng.decode_step()
            except RuntimeError:
                pass                        # growth exhausted: atomic
        elif op == "eos":
            active = [i for i, r in enumerate(eng.slots) if r is not None]
            if active:
                i = active[fam % len(active)]
                eng.slots[i] = None
                eng._release_slot(i)        # the early-EOS release path
        elif op == "release" and pending:
            _, _, p = pending.pop(fam % len(pending))
            eng.release_payload(p)
        elif op == "evict":
            eng.prefix_cache.evict(ln)
        elif op == "preempt":
            active = [i for i, r in enumerate(eng.slots) if r is not None]
            if active:
                eng.preempt_slot(active[fam % len(active)])
        elif op == "resume":
            eng.try_resume()
        # invariant: allocator == slots + tree + un-inserted payloads;
        # swap store == parked requests' handles
        eng.assert_no_page_leaks(
            extra_holders=[p.page_ids for _, _, p in pending])
    for _, _, p in pending:
        eng.release_payload(p)
    for pr in eng.preempted:
        if pr.handle is not None:
            eng.pool.swap_free(pr.handle)
    eng.preempted.clear()
    eng.assert_no_page_leaks()

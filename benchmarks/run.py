# One function per paper table/figure (benchmarks.paper_tables) plus
# kernel/engine microbenchmarks. Prints CSV rows: name,...,derived.
from __future__ import annotations

import sys
import time


def main() -> None:
    t_start = time.time()
    from benchmarks.extensions import EXTENSION_BENCHMARKS
    from benchmarks.kernel_bench import (bench_engine, bench_kernels,
                                         bench_paged_kv)
    from benchmarks.paper_tables import ALL_BENCHMARKS

    only = sys.argv[1] if len(sys.argv) > 1 else None
    for fn in ALL_BENCHMARKS + EXTENSION_BENCHMARKS:
        if only and only not in fn.__name__:
            continue
        t0 = time.time()
        for row in fn():
            print(row)
        print(f"# {fn.__name__} done in {time.time() - t0:.1f}s", flush=True)
    if only is None or "kernel" in only or "engine" in only:
        for row in bench_kernels():
            print(row)
        for row in bench_engine():
            print(row)
    if only is None or "paged" in only:
        for row in bench_paged_kv():
            print(row)
    if only is None or "prefix" in only:
        from benchmarks.prefix_bench import bench_prefix_cache
        for row in bench_prefix_cache():
            print(row)
    if only is None or "chunked" in only:
        from benchmarks.chunked_prefill_bench import bench_chunked_prefill
        for row in bench_chunked_prefill():
            print(row)
    if only is None or "preempt" in only:
        from benchmarks.preemption_bench import bench_preemption
        for row in bench_preemption():
            print(row)
    if only is None or "fault" in only:
        from benchmarks.fault_bench import bench_faults
        for row in bench_faults():
            print(row)
    print(f"# total {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()

"""Observability benchmark / smoke: traced chaos workload + trace export.

Two halves, both seeded and deterministic:

1. REAL cluster (smollm reduced, chunked prefill, wire faults): run
   with tracing ON and assert the telemetry invariants hold under
   chaos — every span balanced, every request's queue/compute/transfer/
   swap/retry components sum to its end-to-end latency (<= 1%), the
   retry component reconciling exactly with the registry's
   retry-time counter — then export the Chrome/Perfetto trace and
   validate it (well-formed events, non-empty Prefill AND Decode
   tracks).

2. SIMULATOR (smollm on simulated time, chunked prefill): the exported
   trace must show the streaming overlap the chunked planner schedules:
   chunk k's ``kv.wire`` span on the P->D link track overlapping chunk
   k+1's ``prefill.chunk`` span on the prefill compute track.

Writes BENCH_observability.json (attribution report + metrics-registry
snapshot under the common ``"telemetry"`` key). ``trace_path`` — wired
to ``benchmarks/run.py --trace out.json`` — additionally writes the
cluster run's Perfetto-loadable trace JSON there.
"""
from __future__ import annotations

import json
import os
from typing import List, Optional


def bench_observability(trace_path: Optional[str] = None) -> List[str]:
    import jax
    from repro.configs import get_config
    from repro.core.cluster import EPDCluster
    from repro.core.faults import SITE_TRANSFER_WIRE, FaultPlan
    from repro.core.simulator import SHAREGPT_4O, simulate
    from repro.core.telemetry import Tracer
    from repro.core.trace_export import (overlap, to_trace_events,
                                         validate_trace, write_trace)
    from repro.models.model import init_params
    from repro.serving.request import Request

    import dataclasses

    rows = ["observability,value,derived"]
    snap = {}

    # ---- 1. REAL cluster: traced chaos run + invariants --------------------
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tracer = Tracer(enabled=True, decode_sample=2)
    cl = EPDCluster(cfg, params, max_batch=2, max_len=96, paged=True,
                    page_size=8, prefix_cache=True, chunked_prefill=True,
                    prefill_chunk=8,
                    faults=FaultPlan(seed=11,
                                     rates={SITE_TRANSFER_WIRE: 0.3}),
                    tracer=tracer)
    reqs = [Request(prompt_tokens=list(range(3 + i, 27 + i)),
                    max_new_tokens=6) for i in range(4)]
    for r in reqs:
        cl.submit(r)
    done = cl.run_until_done()
    assert len(done) == len(reqs) and not cl.report.lost

    tracer.assert_balanced()
    cl.acc.assert_all_closed()
    cl.acc.check_all(tol=0.01)           # components sum to e2e
    att = cl.attribution()
    retry_comp = cl.acc.component_total("retry")
    assert abs(retry_comp - cl.report.retry_time_total) <= 1e-9, \
        "retry component must reconcile with retry_time_seconds_total"

    doc = {"traceEvents": to_trace_events(tracer),
           "displayTimeUnit": "ms"}
    counts = validate_trace(doc, require_tracks=["P0", "D0"])
    if trace_path:
        n = write_trace(tracer, trace_path)
        rows.append(f"trace_written,{n},events_to_{trace_path}")
    snap["cluster"] = {
        "n_requests": len(done),
        "transfer_retries": cl.report.transfer_retries,
        "retry_time_ms": round(cl.report.retry_time_total * 1e3, 3),
        "trace_tracks": counts,
        "attribution": att,
    }
    snap["telemetry"] = cl.metrics.snapshot()
    rows.append(f"cluster_spans,{sum(counts.values())},"
                f"tracks_{'_'.join(sorted(counts))}")
    rows.append(f"cluster_attribution,sum_eq_e2e,"
                f"mean_e2e_{att['mean_e2e_ms']}ms")

    # ---- 2. simulator: chunk-k wire under chunk-k+1 compute ----------------
    # long prompts + 1k-token chunks: per-chunk compute must exceed the
    # link handshake or every group just queues behind it (no overlap)
    model = get_config("deepseek-7b")
    ds = dataclasses.replace(SHAREGPT_4O, mm_fraction=0.0,
                             text_tokens_mean=4096.0, output_tokens=8)
    sim_tr = Tracer(enabled=True)
    m = simulate(model, "E-P-D", ds, rate=2.0, n_requests=6, seed=3,
                 kv_page_tokens=16, chunked_prefill=True,
                 prefill_chunk_tokens=1024, tracer=sim_tr)
    sim_doc = {"traceEvents": to_trace_events(sim_tr),
               "displayTimeUnit": "ms"}
    sim_tracks = validate_trace(sim_doc)
    p_track = next(t for t, n in sim_tr.tracks().items()
                   if "->" not in t and any(
                       s.track == t and s.name == "prefill.chunk"
                       for s in sim_tr.spans))
    link = next(t for t in sim_tr.tracks() if "->" in t)
    ov = overlap(sim_doc, p_track, "prefill.chunk", link, "kv.wire")
    assert ov > 0, "chunked streaming must overlap transfer with compute"
    # the specific schedule shape: chunk k's wire span rides under chunk
    # k+1's compute span. The sim's plan prepends a cached-prefix
    # segment, so plan group g is compute chunk g-1 and its wire rides
    # under compute chunk g.
    chunk_spans = [s for s in sim_tr.spans if s.name == "prefill.chunk"]
    wire_spans = [s for s in sim_tr.spans if s.name == "kv.wire"]
    adjacent = any(
        w.request_id == c.request_id
        and c.attrs.get("chunk") == w.attrs.get("group", -2)
        and min(w.end, c.end) > max(w.start, c.start)
        for w in wire_spans for c in chunk_spans)
    assert adjacent, "no chunk-k wire span overlapped chunk-k+1 compute"
    # attribution invariant holds on simulated time too
    for r in m.attribution["requests"]:
        s = sum(r["components_ms"].values())
        assert abs(s - r["e2e_ms"]) <= 0.01 * max(r["e2e_ms"], 1e-6) + 1e-6
    snap["simulator"] = {
        "overlap_ms": round(ov * 1e3, 4),
        "trace_tracks": sim_tracks,
        "mean_components_ms": m.attribution["mean_components_ms"],
    }
    rows.append(f"sim_stream_overlap,{ov * 1e3:.2f}ms,"
                f"chunk_k_wire_under_chunk_k+1_compute")

    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_observability.json")
    with open(os.path.abspath(out), "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows


if __name__ == "__main__":
    for row in bench_observability():
        print(row)

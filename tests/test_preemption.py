"""Page-level preemption with host-memory swap and re-fault.

The harness this PR exists for: a request preempted mid-decode and
resumed later must emit the EXACT token sequence of an uninterrupted
run — across preemption timing (after 1 step, mid-stream, repeatedly),
engine modes (paged, prefix_cache, chunked_prefill), and page-boundary
positions — with zero leaked pages and zero dangling swap handles.
Plus: the typed PoolExhausted surface, organic pressure-driven
preemption, swap-handle audits, cluster overload end-to-end, and the
simulator preemption-vs-kill A/B (acceptance)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cluster import EPDCluster
from repro.core.simulator import SHAREGPT_4O, simulate
from repro.serving.kv_pool import PagePool, PoolExhausted
from repro.serving.request import Request


@pytest.fixture(scope="module")
def smollm():
    from repro.models.model import init_params
    cfg = get_config("smollm-135m").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


MODES = ("paged", "prefix_cache", "chunked_prefill")


def _engine(cfg, params, mode, *, preemption=True, page=8, max_len=64,
            **kw):
    from repro.serving.engine import Engine
    if mode == "prefix_cache":
        kw.setdefault("prefix_cache", True)
        kw.setdefault("n_pool_pages", 64)
    elif mode == "chunked_prefill":
        kw.setdefault("prefix_cache", True)
        kw.setdefault("chunked_prefill", True)
        kw.setdefault("prefill_chunk", 16)
        kw.setdefault("n_pool_pages", 64)
    return Engine(cfg, params, max_batch=2, max_len=max_len, paged=True,
                  page_size=page, preemption=preemption, **kw)


def _serve(eng, prompt, n=8, preempt_at=()):
    """Serve one request, force-preempting its slot before the decode
    steps named in ``preempt_at`` (decode_step resumes it as soon as
    pages allow — same step here, since preemption frees them)."""
    r = Request(prompt_tokens=list(prompt), max_new_tokens=n)
    f, p = eng.prefill_request(r)
    eng.insert(r, p, f)
    step = 0
    while (any(s is r for s in eng.slots)
           or any(pr.req is r for pr in eng.preempted)):
        if step in preempt_at and any(s is r for s in eng.slots):
            eng.preempt_slot(next(i for i, s in enumerate(eng.slots)
                                  if s is r))
        eng.decode_step()
        step += 1
        assert step < 200, "preempted request never finished"
    return r.output_tokens


# ---------------------------------------------------------------------------
# greedy parity: preempt/resume == uninterrupted, all modes x timings
# ---------------------------------------------------------------------------

# page = 8: prompts end inside a page, exactly on a boundary, and one
# past it, so preemption hits every block-table edge case.
PROMPTS = (list(range(2, 15)),          # 13 tokens: mid-page
           list(range(2, 18)),          # 16 tokens: exact page boundary
           list(range(2, 19)))          # 17 tokens: one past a boundary


@pytest.mark.parametrize("mode", MODES)
def test_preempt_resume_greedy_parity(smollm, mode):
    """Preempt after 1 step / mid-stream / repeatedly: outputs are
    byte-identical to the uninterrupted run, pages and swap handles
    balance after every serve."""
    cfg, params = smollm
    base = _engine(cfg, params, mode, preemption=False)
    eng = _engine(cfg, params, mode)
    for prompt in PROMPTS:
        want = _serve(base, prompt)
        for when in ((0,), (3,), (0, 2, 4, 6)):
            got = _serve(eng, prompt, preempt_at=when)
            assert got == want, (mode, len(prompt), when)
            eng.assert_no_page_leaks()
    assert eng.preempt_count >= 9
    assert eng.resume_count == eng.preempt_count
    assert not eng.preempted
    base.assert_no_page_leaks()


def test_preempt_at_page_boundary_positions(smollm):
    """Preempt exactly when the sequence length sits on / one past a
    page boundary (the growth-path hot spot)."""
    cfg, params = smollm
    base = _engine(cfg, params, "paged", preemption=False)
    eng = _engine(cfg, params, "paged")
    prompt = list(range(2, 15))                    # 13 tokens + 1 first tok
    want = _serve(base, prompt, n=10)
    # len after prefill+first = 14; steps 1/2/3 put the boundary (16)
    # before, at, and after the preemption point
    for when in ((1,), (2,), (3,)):
        got = _serve(eng, prompt, n=10, preempt_at=when)
        assert got == want, when
        eng.assert_no_page_leaks()


def test_preempted_request_parks_until_pages_free(smollm):
    """With another request holding the pool, a preempted request stays
    parked (resume genuinely deferred) and still matches the
    uninterrupted output when it finally resumes."""
    cfg, params = smollm
    from repro.serving.engine import Engine
    base = Engine(cfg, params, max_batch=2, max_len=64, paged=True,
                  page_size=4)
    a0 = Request(prompt_tokens=list(range(2, 18)), max_new_tokens=20)
    f, p = base.prefill_request(a0)
    base.insert(a0, p, f)
    while base.n_active:
        base.decode_step()

    eng = Engine(cfg, params, max_batch=2, max_len=64, paged=True,
                 page_size=4, preemption=True, n_pool_pages=13)
    a = Request(prompt_tokens=list(range(2, 18)), max_new_tokens=20)
    b = Request(prompt_tokens=list(range(30, 46)), max_new_tokens=20)
    for r in (a, b):
        f, p = eng.prefill_request(r)
        eng.insert(r, p, f)
    # 12 usable pages, both requests grow from 4 to 9 pages: growth must
    # preempt one victim organically, resume it after the other finishes
    steps = 0
    while eng.n_active or eng.preempted:
        eng.decode_step()
        steps += 1
        assert steps < 200
    assert eng.preempt_count >= 1
    assert len(a.output_tokens) == 20 and len(b.output_tokens) == 20
    assert a.output_tokens == a0.output_tokens      # victim or survivor
    assert max(a.n_preempts, b.n_preempts) >= 1
    eng.assert_no_page_leaks()
    assert eng.pool.n_used == 0


# ---------------------------------------------------------------------------
# PoolExhausted: the typed surface the trigger (and tests) assert on
# ---------------------------------------------------------------------------

def test_pool_exhausted_is_typed():
    pool = PagePool(4, page_size=8)
    pool.alloc(3)
    with pytest.raises(PoolExhausted) as ei:
        pool.alloc(2)
    assert ei.value.requested == 2
    assert ei.value.n_free == 0
    assert ei.value.n_usable == 3
    assert isinstance(ei.value, RuntimeError)      # legacy catches survive
    assert "exhausted" in str(ei.value)            # legacy matches survive


# (the engine growth path surfacing the typed error is covered by
# test_engine_edge.py::test_preemption_disabled_preserves_kill_behavior)


def test_simulator_kills_request_larger_than_pool():
    """A request whose KV can never fit the decode pool is dropped at
    admission in BOTH modes instead of head-of-line blocking decode_wait
    forever (preemption cannot shrink a request)."""
    model = get_config("openpangu-7b-vl")
    ds = dataclasses.replace(SHAREGPT_4O, mm_fraction=0.0,
                             text_tokens_mean=332.0, output_tokens=8)
    kw = dict(rate=4.0, n_requests=4, seed=0, kv_page_tokens=16,
              decode_kv_pages=20)                 # < one request's pages
    for preemption in (False, True):
        m = simulate(model, "E-P-D", ds, preemption=preemption, **kw)
        assert m.killed_requests > 0
        assert m.completed_requests + m.killed_requests == 4
        # fit-able requests behind the oversized ones still complete
        assert m.completed_requests > 0


# ---------------------------------------------------------------------------
# swap space: handle lifecycle + audit
# ---------------------------------------------------------------------------

def test_swap_handle_lifecycle_and_audit():
    pool = PagePool(9, page_size=8)
    ids = pool.alloc(4)
    h = pool.swap_out(ids[2:], data={"kv": np.arange(4)})
    assert pool.n_used == 2
    assert pool.n_swapped_pages == 2
    pool.assert_balanced([ids[:2]], swap_handles=[h])
    # an unknown holder set must fail the audit both ways
    with pytest.raises(AssertionError, match="leaked swap"):
        pool.assert_balanced([ids[:2]])
    back, data = pool.swap_in(h)
    assert len(back) == 2 and data["kv"].sum() == 6
    pool.assert_balanced([ids[:2], back])
    with pytest.raises(AssertionError, match="dangling swap"):
        pool.assert_balanced([ids[:2], back], swap_handles=[h])
    with pytest.raises(ValueError, match="consumed"):
        pool.swap_in(h)
    # swap_in under exhaustion keeps the handle retryable
    h2 = pool.swap_out(back, data=None)
    blocker = pool.alloc(pool.n_free)
    with pytest.raises(PoolExhausted):
        pool.swap_in(h2)
    pool.assert_balanced([ids[:2], blocker], swap_handles=[h2])
    pool.free(blocker)
    again, _ = pool.swap_in(h2)
    assert len(again) == 2
    pool.swap_out(again)
    # abandoning: swap_free drops the entry exactly once
    h3 = [hh for hh in [pool.swap_out(ids[:2])]][0]
    pool.swap_free(h3)
    with pytest.raises(ValueError, match="double free"):
        pool.swap_free(h3)


def test_engine_audits_swap_handles(smollm):
    """assert_no_page_leaks covers the preempted queue: a parked request
    holds no device pages but its swap handle must exist in the store."""
    cfg, params = smollm
    eng = _engine(cfg, params, "paged")
    r = Request(prompt_tokens=list(range(2, 15)), max_new_tokens=8)
    f, p = eng.prefill_request(r)
    eng.insert(r, p, f)
    eng.decode_step()
    pr = eng.preempt_slot(0)
    assert pr.handle is not None
    assert eng.pool.n_swapped_pages == pr.handle.n_pages
    eng.assert_no_page_leaks()                    # handle accounted for
    # dropping the record without freeing the handle is a detected leak
    eng.preempted.clear()
    with pytest.raises(AssertionError, match="leaked swap"):
        eng.assert_no_page_leaks()
    eng.pool.swap_free(pr.handle)
    eng.assert_no_page_leaks()


# ---------------------------------------------------------------------------
# cluster: overload end-to-end (real compute)
# ---------------------------------------------------------------------------

def test_cluster_preemption_survives_overload(smollm):
    """Same tight decode pool: the preemption cluster completes every
    request (with swaps); the baseline dies on PoolExhausted — the old
    kill behavior the A/B replaces."""
    cfg, params = smollm

    def run(preemption):
        cl = EPDCluster(cfg, params, max_batch=3, max_len=64, paged=True,
                        page_size=8, preemption=preemption,
                        n_decode_pool_pages=11)    # 10 usable pages
        reqs = [Request(prompt_tokens=list(range(2 + i, 18 + i)),
                        max_new_tokens=24) for i in range(5)]
        for r in reqs:
            cl.submit(r)
        done = cl.run_until_done(max_steps=600)
        return cl, done, reqs

    cl, done, reqs = run(True)
    assert len(done) == 5
    assert all(len(r.output_tokens) == 24 for r in reqs)
    assert cl.report.preemptions >= 1
    assert cl.report.swapped_pages > 0
    cl.decode_engine.assert_no_page_leaks()
    cl.prefill_engine.assert_no_page_leaks()
    assert cl.decode_engine.pool.n_used == 0
    with pytest.raises(PoolExhausted):
        run(False)


# ---------------------------------------------------------------------------
# simulator A/B (acceptance): preemption completes strictly more than
# the kill baseline at the same pool size
# ---------------------------------------------------------------------------

def test_simulator_preemption_beats_kill_baseline():
    model = get_config("openpangu-7b-vl")
    ds = dataclasses.replace(SHAREGPT_4O, mm_fraction=0.0,
                             text_tokens_mean=256.0, output_tokens=96)
    # decode pool ~60% of peak demand (48 near-simultaneous requests
    # x ~22 pages at page 16)
    kw = dict(rate=32.0, n_requests=48, seed=3, kv_page_tokens=16,
              decode_kv_pages=400)
    kill = simulate(model, "E-P-D", ds, **kw)
    pre = simulate(model, "E-P-D", ds, preemption=True, **kw)
    assert kill.killed_requests > 0
    assert kill.completed_requests == 48 - kill.killed_requests
    assert pre.killed_requests == 0
    assert pre.n_preemptions > 0
    assert pre.completed_requests == 48
    assert pre.completed_requests > kill.completed_requests
    # preempted requests pay swap + parking time: TPOT degrades
    # gracefully instead of requests dying
    assert pre.mean_tpot_ms > 0


def test_simulator_capacity_without_preemption_unpressured():
    """A bounded pool above demand behaves exactly like the unbounded
    legacy path (no kills, no preemptions, same metrics)."""
    model = get_config("openpangu-7b-vl")
    ds = dataclasses.replace(SHAREGPT_4O, mm_fraction=0.0)
    kw = dict(rate=4.0, n_requests=32, seed=1, kv_page_tokens=16)
    a = simulate(model, "E-P-D", ds, **kw)
    b = simulate(model, "E-P-D", ds, decode_kv_pages=10_000, **kw)
    c = simulate(model, "E-P-D", ds, decode_kv_pages=10_000,
                 preemption=True, **kw)
    for m in (a, b, c):
        assert m.killed_requests == 0
        assert m.n_preemptions == 0
        assert m.completed_requests == 32
    assert a.mean_ttft_ms == pytest.approx(b.mean_ttft_ms)
    assert b.mean_tpot_ms == pytest.approx(c.mean_tpot_ms)


def test_costmodel_swap_time():
    from repro.core.costmodel import CostModel
    cost = CostModel(get_config("openpangu-7b-vl"), page_tokens=16)
    assert cost.swap_time(0) == 0.0
    t1, t8 = cost.swap_time(1), cost.swap_time(8)
    assert t1 > cost.hw.swap_latency
    # linear in pages past the fixed latency
    assert t8 - cost.hw.swap_latency == pytest.approx(
        8 * (t1 - cost.hw.swap_latency))
    dense = CostModel(get_config("openpangu-7b-vl"))
    with pytest.raises(ValueError, match="paged"):
        dense.swap_time(4)

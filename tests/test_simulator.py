"""EPD simulator: invariants + the paper's qualitative claims."""
import dataclasses

import pytest

from repro.configs import get_config
from repro.core.simulator import (SHAREGPT_4O, VISUALWEB, SimConfig,
                                  Simulator, gen_requests, simulate)

MODEL = get_config("openpangu-7b-vl")


def _run(dep, rate=6.0, n=192, **kw):
    return simulate(MODEL, dep, SHAREGPT_4O, rate=rate, n_requests=n,
                    seed=7, **kw)


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dep", ["TP1", "TP2", "E-PD", "(E-PD)", "EP-D",
                                 "(E-P)-D", "(E-D)-P", "E-P-D"])
def test_all_requests_complete_all_deployments(dep):
    m = _run(dep, rate=4.0, n=96)
    assert len(m.requests) == 96
    for r in m.requests:
        assert len(r.output_tokens) == r.max_new_tokens
        assert r.t_first_token >= r.t_arrival
        assert r.t_done >= r.t_first_token
        assert r.ttft > 0 and r.tpot > 0


def test_timestamps_monotone_through_pipeline():
    m = _run("E-P-D", rate=4.0, n=96)
    for r in m.requests:
        if r.is_multimodal and r.t_encode_start >= 0:
            assert r.t_arrival <= r.t_encode_start <= r.t_encode_done
            assert r.t_encode_done <= r.t_prefill_start + 1e-9


def test_text_only_requests_skip_encode():
    ds = dataclasses.replace(VISUALWEB, mm_fraction=0.5)
    m = simulate(MODEL, "E-P-D", ds, rate=4.0, n_requests=128, seed=3)
    text = [r for r in m.requests if not r.is_multimodal]
    assert text, "workload should contain text-only requests"
    for r in text:
        assert r.t_encode_start < 0          # never touched Encode


def test_mm_store_dedup_reduces_encodes():
    ds = dataclasses.replace(SHAREGPT_4O, unique_images=8)
    m = simulate(MODEL, "E-P-D", ds, rate=4.0, n_requests=128, seed=3)
    assert m.store_hit_rate > 0.5            # 128 reqs, 8 unique images


# ---------------------------------------------------------------------------
# paper claims (qualitative)
# ---------------------------------------------------------------------------

def test_decode_disaggregation_stabilizes_tpot():
    """Paper §4.4: decode-disaggregated deployments have far lower TPOT
    than monolithic under load."""
    mono = _run("TP1", rate=8.0)
    disagg = _run("(E-P)-D", rate=8.0)
    assert disagg.mean_tpot_ms < mono.mean_tpot_ms / 2


def test_ep_colocation_beats_coupled_ep():
    """Paper §4.4: (E-P)-D (spatial multiplexing) beats EP-D (serial
    coupling) on TTFT under load."""
    coupled = _run("EP-D", rate=8.0)
    coloc = _run("(E-P)-D", rate=8.0)
    assert coloc.mean_ttft_ms < coupled.mean_ttft_ms


def test_ed_colocation_best_ttft():
    """Paper §4.7: (E-D)-P excels at TTFT (complementary co-location)."""
    edp = _run("(E-D)-P", rate=8.0)
    epd = _run("(E-P)-D", rate=8.0)
    ep_d = _run("EP-D", rate=8.0)
    assert edp.mean_ttft_ms <= epd.mean_ttft_ms
    assert edp.mean_ttft_ms <= ep_d.mean_ttft_ms
    # ...at slight TPOT cost vs the cleanest decode isolation
    assert edp.mean_tpot_ms >= ep_d.mean_tpot_ms * 0.99


def test_full_epd_highest_slo_under_load():
    """Paper Table 5: E-P-D achieves the best SLO attainment at high load."""
    rows = {d: _run(d, rate=8.0) for d in
            ["TP1", "(E-PD)", "EP-D", "(E-P)-D", "E-P-D"]}
    slo = {d: m.slo_attainment(2000, 50) for d, m in rows.items()}
    assert slo["E-P-D"] >= max(slo.values()) - 1e-9
    assert slo["E-P-D"] > slo["TP1"]
    assert slo["(E-P)-D"] > slo["EP-D"] - 1e-9


def test_transmission_optimizations_reduce_ttft():
    """Paper Table 2: both mechanisms cut TTFT; combined cuts most."""
    base = _run("E-P-D", rate=3.0, kv_scheme="layer_wise", ep_async=False)
    ep = _run("E-P-D", rate=3.0, kv_scheme="layer_wise", ep_async=True)
    kv = _run("E-P-D", rate=3.0, kv_scheme="grouped", ep_async=False)
    both = _run("E-P-D", rate=3.0, kv_scheme="grouped", ep_async=True)
    assert ep.mean_ttft_ms < base.mean_ttft_ms
    assert kv.mean_ttft_ms < base.mean_ttft_ms
    # combined is best up to queueing noise (stochastic arrival ordering)
    assert both.mean_ttft_ms < min(ep.mean_ttft_ms, kv.mean_ttft_ms) * 1.02
    assert both.mean_ttft_ms < base.mean_ttft_ms * 0.85


def test_per_chip_normalization():
    m1 = _run("E-P-D", rate=4.0, n=96)
    assert m1.n_chips == 3
    eff_total = m1.effective_throughput(2000, 50, per_chip=False)
    eff_chip = m1.effective_throughput(2000, 50, per_chip=True)
    assert eff_chip == pytest.approx(eff_total / 3)


def test_gen_requests_poisson_rate():
    reqs = gen_requests(SHAREGPT_4O, 2000, rate=10.0, seed=0)
    span = reqs[-1].t_arrival - reqs[0].t_arrival
    assert 2000 / span == pytest.approx(10.0, rel=0.15)

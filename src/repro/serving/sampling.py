"""Token sampling."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits, key=None, temperature: float = 0.0):
    """logits: (B, vocab) f32 -> (B,) int32."""
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)

"""Per-architecture smoke tests + cache-semantics correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.model import (decode_forward, init_params, prefill_forward,
                                train_forward)
from repro.models.params import count_params
from repro.models.transformer import make_caches
from repro.training.optimizer import AdamW
from repro.training.train import make_train_step


def _mk(arch, dropless=False):
    cfg = get_config(arch).reduced()
    if dropless and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _batch(cfg, b=2, s=16, key=None):
    key = key or jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
    }
    if cfg.frontend is not None and cfg.encoder is None:
        batch["mm_embeds"] = jnp.full((b, 4, cfg.frontend.feature_dim), 0.01)
    if cfg.encoder is not None:
        batch["enc_frames"] = jnp.full(
            (b, cfg.encoder.n_ctx, cfg.frontend.feature_dim), 0.01)
    return batch


# ---------------------------------------------------------------------------
# (f) REDUCED-config smoke tests: one forward + one train step per arch
# ---------------------------------------------------------------------------

SMOKE_ARCHS = ASSIGNED_ARCHS + ("openpangu-7b-vl",)   # + the paper's model


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg, params = _mk(arch)
    assert count_params(params) < 20_000_000
    batch = _batch(cfg)
    loss, metrics = train_forward(params, cfg, batch, remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt, remat=False))
    new_params, _, m = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(m["loss"]))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree.leaves(new_params), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_smoke_serve_step(arch):
    """prefill + one decode step: output shapes + no NaNs."""
    cfg, params = _mk(arch)
    b, s = 2, 12
    batch = _batch(cfg, b, s)
    caches = make_caches(cfg, b, 32, dtype=jnp.float32)
    logits, caches = prefill_forward(
        params, cfg, batch["tokens"], caches,
        lengths=jnp.array([s + (4 if "mm_embeds" in batch else 0)] * b),
        mm_embeds=batch.get("mm_embeds"), enc_frames=batch.get("enc_frames"))
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits, -1)
    logits2, caches = decode_forward(params, cfg, tok, caches)
    assert logits2.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))


# ---------------------------------------------------------------------------
# cache semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm-135m", "mixtral-8x7b",
                                  "mamba2-370m", "jamba-v0.1-52b",
                                  "whisper-base", "deepseek-7b"])
def test_decode_matches_prefill(arch):
    """Decoding token t against a cache prefilled to t-1 must equal
    prefilling all t tokens (MoE archs: dropless capacity)."""
    cfg, params = _mk(arch, dropless=True)
    b, s = 2, 12
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    ef = (jnp.full((b, cfg.encoder.n_ctx, cfg.frontend.feature_dim), 0.01)
          if cfg.encoder else None)
    cA = make_caches(cfg, b, 32, dtype=jnp.float32)
    lA = jnp.array([s] * b)
    logA, _ = prefill_forward(params, cfg, toks, cA, lengths=lA,
                              enc_frames=ef)
    cB = make_caches(cfg, b, 32, dtype=jnp.float32)
    logB0, cB = prefill_forward(params, cfg, toks[:, :s - 1], cB,
                                lengths=jnp.array([s - 1] * b), enc_frames=ef)
    logB, _ = decode_forward(params, cfg, toks[:, s - 1], cB)
    np.testing.assert_allclose(np.asarray(logA), np.asarray(logB),
                               atol=2e-3, rtol=1e-3)


def test_swa_ring_buffer_wraparound():
    """Sliding-window decode with a window-sized ring buffer must equal
    decode with an oversized (never-wrapping) cache."""
    cfg, params = _mk("mixtral-8x7b", dropless=True)
    cfg = dataclasses.replace(cfg, sliding_window=8)
    b, prefill_len, n_decode = 1, 6, 10
    key = jax.random.PRNGKey(5)
    toks = jax.random.randint(key, (b, prefill_len + n_decode), 0, cfg.vocab)

    def run(cache_len, for_decode):
        caches = make_caches(cfg, b, cache_len, dtype=jnp.float32,
                             for_decode=for_decode)
        logits, caches = prefill_forward(
            params, cfg, toks[:, :prefill_len], caches,
            lengths=jnp.array([prefill_len] * b))
        outs = []
        for i in range(n_decode):
            logits, caches = decode_forward(
                params, cfg, toks[:, prefill_len + i], caches)
            outs.append(logits)
        return jnp.stack(outs)

    big = run(64, for_decode=False)       # cache never wraps
    ring = run(64, for_decode=True)       # window-sized ring buffer (8)
    np.testing.assert_allclose(np.asarray(big), np.asarray(ring),
                               atol=2e-3, rtol=1e-3)


def test_padding_invariance():
    """Prefill with right-padding must give the same last-token logits."""
    cfg, params = _mk("smollm-135m")
    b, s = 1, 10
    toks = jax.random.randint(jax.random.PRNGKey(7), (b, s), 0, cfg.vocab)
    c1 = make_caches(cfg, b, 32, dtype=jnp.float32)
    l1, _ = prefill_forward(params, cfg, toks, c1, lengths=jnp.array([s]))
    padded = jnp.pad(toks, ((0, 0), (0, 6)))
    c2 = make_caches(cfg, b, 32, dtype=jnp.float32)
    l2, _ = prefill_forward(params, cfg, padded, c2, lengths=jnp.array([s]))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=2e-4, rtol=1e-4)


def test_mm_embeddings_change_output():
    cfg, params = _mk("llava-next-mistral-7b")
    b, s = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(9), (b, s), 0, cfg.vocab)
    mm1 = jnp.full((b, 4, cfg.frontend.feature_dim), 0.01)
    mm2 = jnp.full((b, 4, cfg.frontend.feature_dim), -0.05)
    outs = []
    for mm in (mm1, mm2):
        c = make_caches(cfg, b, 32, dtype=jnp.float32)
        lg, _ = prefill_forward(params, cfg, toks, c,
                                lengths=jnp.array([s + 4]), mm_embeds=mm)
        outs.append(np.asarray(lg))
    assert np.abs(outs[0] - outs[1]).max() > 1e-4

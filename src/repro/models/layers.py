"""Core transformer layers: RMSNorm, RoPE, GQA attention (full / sliding
window, prefill / decode), gated MLP.

All math is pure jnp (this is also the dry-run / roofline path); the
Pallas kernels in ``repro.kernels`` are drop-in replacements dispatched in
``ops.py`` when running on real TPU.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as K
from repro.models.partitioning import shard

_NEG_INF = -1e30


def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope(x, positions, theta: float):
    """x: (b, s, heads, head_dim), positions: (b, s) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (b, s, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


class AttnCache(NamedTuple):
    """Per-pattern-position stacked KV cache.

    k, v:    (repeats, batch, S, n_kv, head_dim)
    kv_pos:  (repeats, batch, S) int32, -1 = empty slot. Sliding-window
             archs use the cache as a ring buffer; kv_pos carries the
             absolute position each slot holds so masking stays exact.
    """

    k: jax.Array
    v: jax.Array
    kv_pos: jax.Array


def make_attn_cache(cfg: ModelConfig, n_repeats: int, batch: int, max_len: int,
                    window: Optional[int], dtype=jnp.bfloat16,
                    abstract: bool = False):
    s = min(max_len, window) if window else max_len
    kshape = (n_repeats, batch, s, cfg.n_kv_heads, cfg.head_dim)
    pshape = (n_repeats, batch, s)
    if abstract:
        return AttnCache(jax.ShapeDtypeStruct(kshape, dtype),
                         jax.ShapeDtypeStruct(kshape, dtype),
                         jax.ShapeDtypeStruct(pshape, jnp.int32))
    return AttnCache(jnp.zeros(kshape, dtype), jnp.zeros(kshape, dtype),
                     jnp.full(pshape, -1, jnp.int32))


class PagedAttnCache(NamedTuple):
    """Per-pattern-position paged KV pool, shared by all decode slots.

    k, v: (repeats, n_pages, page_size, n_kv, head_dim). Physical page 0
    is reserved as the trash page (see serving.kv_pool): unmapped
    block-table entries point there, so stray writes never corrupt live
    pages. Token t of a slot lives in logical page t // page_size at
    offset t % page_size; the slot's block table row maps logical pages
    to physical ones. No kv_pos array is needed — positions are implied
    by page geometry and masked by per-slot length.
    """

    k: jax.Array
    v: jax.Array


def make_paged_attn_cache(cfg: ModelConfig, n_repeats: int, n_pages: int,
                          page_size: int, dtype=jnp.bfloat16,
                          abstract: bool = False):
    shape = (n_repeats, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    if abstract:
        return PagedAttnCache(jax.ShapeDtypeStruct(shape, dtype),
                              jax.ShapeDtypeStruct(shape, dtype))
    return PagedAttnCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def compute_cross_kv(p, enc_out, enc_pos, cfg: ModelConfig):
    """Precompute cross-attention KV from encoder output (once per request)."""
    k = _split_heads(enc_out @ p["xwk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(enc_out @ p["xwv"], cfg.n_kv_heads, cfg.head_dim)
    return k, v, enc_pos


def cross_attention_block(p, x, positions, enc_kv, cfg: ModelConfig):
    """Cross-attention with precomputed encoder KV; residual included."""
    k, v, enc_pos = enc_kv
    h = rms_norm(x, p["xnorm"], cfg.norm_eps)
    q = _split_heads(h @ p["xwq"], cfg.n_heads, cfg.head_dim)
    q = shard(q, "batch", None, "act_heads", None)
    out = K.attention(q, k.astype(q.dtype), v.astype(q.dtype),
                      positions, enc_pos, causal=False)
    out = out.reshape(x.shape[0], x.shape[1], cfg.q_dim)
    return x + shard(out @ p["xwo"], "batch", None, "act_embed")


def attention_block(p, x, positions, cfg: ModelConfig, *, window=None,
                    cache: Optional[Tuple[jax.Array, ...]] = None,
                    cur_len: Optional[jax.Array] = None,
                    causal: bool = True,
                    pages: Optional[jax.Array] = None,
                    prefix_len: Optional[jax.Array] = None,
                    pos_base: Optional[jax.Array] = None):
    """One self-attention sub-block with residual.

    cache: per-repeat cache views. Dense: (k_cache, v_cache, kv_pos) —
      (b, S, nkv, hd) / (b, S). When given and x is a single decode
      token, the new KV is written at slot ``cur_len % S`` (ring buffer;
      S == max_len for full attention so the modulo is a no-op until
      overflow).
    pages: (b, max_pages) int32 block table — switches the cache to the
      PAGED layout: cache is (k_pool, v_pool) with shape
      (n_pages, page, nkv, hd). Decode writes one token into its slot's
      current page; prefill scatters the sequence's pages into the pool
      (tokens past a slot's mapped pages land on the trash page 0).
    prefix_len / pos_base: SUFFIX prefill against a cached prefix (the
      prefix-cache hit path, batch 1). The first ``prefix_len`` tokens of
      the sequence already sit in pool pages mapped by the block table;
      ``x`` holds only the tokens from the page-aligned ``pos_base``
      onward (entries below ``prefix_len`` are dummies with position -1).
      Queries attend to the gathered prefix KV plus the in-batch suffix,
      and the scatter is masked per token so the copied-on-write partial
      page keeps its prefix tokens.
    Returns (out, new_cache_views_or_None).
    """
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    # q/k/v are constrained on their HEAD axes. When an arch's head count
    # doesn't divide the model axis (whisper 8, smollm 9, glm4 kv=2) the
    # divisibility guard in shard() turns the constraint into explicit
    # replication — far cheaper than letting propagation split head_dim,
    # which makes every QK^T contraction a partial-sum + all-reduce over
    # the (s, S) score tensors (measured 52 GB/step on whisper prefill).
    q = _split_heads(h @ p["wq"], cfg.n_heads, cfg.head_dim)
    q = shard(q, "batch", None, "act_heads", None)
    k = _split_heads(h @ p["wk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(h @ p["wv"], cfg.n_kv_heads, cfg.head_dim)
    k = shard(k, "batch", None, "act_kv_heads", None)
    v = shard(v, "batch", None, "act_kv_heads", None)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and pages is not None:
        # ---- paged KV pool: (n_pages, page, nkv, hd) shared by slots ----
        ck, cv = cache
        page = ck.shape[1]
        b = x.shape[0]
        if x.shape[1] == 1:
            # decode: write one token into the slot's current page
            pos = cur_len.astype(jnp.int32)                       # (b,)
            pidx = jnp.clip(pos // page, 0, pages.shape[1] - 1)
            phys = jnp.take_along_axis(pages, pidx[:, None], 1)[:, 0]
            off = pos % page
            ck = ck.at[phys, off].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[phys, off].set(v[:, 0].astype(cv.dtype))
            new_cache = (ck, cv)
            out = K.paged_attention(q[:, 0], ck, cv, pages, pos + 1,
                                    window=window)[:, None]
        elif prefix_len is None:
            # prefill: scatter the (padded) sequence's pages into the pool
            S = k.shape[1]
            if S % page:
                raise ValueError(
                    f"paged prefill length {S} not a multiple of page {page}")
            npg = S // page
            if npg > pages.shape[1]:
                raise ValueError("prefill longer than block table")
            flat = pages[:, :npg].reshape(-1)
            kp = k.reshape(b * npg, page, *k.shape[2:])
            vp = v.reshape(b * npg, page, *v.shape[2:])
            ck = ck.at[flat].set(kp.astype(ck.dtype))
            cv = cv.at[flat].set(vp.astype(cv.dtype))
            new_cache = (ck, cv)
            out = K.attention(q, k, v, positions, positions, window=window)
        else:
            # ---- suffix prefill against a cached prefix (batch 1) ----
            S = k.shape[1]
            if S % page:
                raise ValueError(
                    f"paged prefill length {S} not a multiple of page {page}")
            if b != 1:
                raise ValueError("suffix prefill is batch-1 only")
            npg = S // page
            start = (pos_base // page).astype(jnp.int32)
            row = jax.lax.dynamic_slice(pages, (0, start), (b, npg))
            flat = row.reshape(-1)
            kp = k.reshape(b * npg, page, *k.shape[2:]).astype(ck.dtype)
            vp = v.reshape(b * npg, page, *v.shape[2:]).astype(cv.dtype)
            # token-masked scatter: dummy positions (the CoW page's copied
            # prefix tokens and the right padding) keep the pool's values
            keep = (positions >= 0).reshape(b * npg, page)[..., None, None]
            ck = ck.at[flat].set(jnp.where(keep, kp, ck[flat]))
            cv = cv.at[flat].set(jnp.where(keep, vp, cv[flat]))
            new_cache = (ck, cv)
            # gather the cached prefix through the whole block-table row;
            # slots at/after prefix_len are masked out (suffix attention
            # runs over the in-batch k/v, unmapped slots hit trash page 0)
            width = pages.shape[1]
            pk = ck[pages.reshape(-1)].reshape(b, width * page, *k.shape[2:])
            pv = cv[pages.reshape(-1)].reshape(b, width * page, *v.shape[2:])
            span = jnp.arange(width * page, dtype=jnp.int32)[None]
            pfx_pos = jnp.where(span < prefix_len, span, -1)
            k_all = jnp.concatenate([pk.astype(q.dtype), k], axis=1)
            v_all = jnp.concatenate([pv.astype(q.dtype), v], axis=1)
            kv_pos = jnp.concatenate(
                [jnp.broadcast_to(pfx_pos, (b, width * page)), positions],
                axis=1)
            out = K.attention(q, k_all, v_all, positions, kv_pos,
                              window=window)
    elif cache is not None:
        ck, cv, cpos = cache
        S = ck.shape[1]
        if x.shape[1] == 1:
            # ---- decode: write one token into the ring buffer ----
            slot = (cur_len % S).astype(jnp.int32)          # (b,)
            bidx = jnp.arange(x.shape[0])
            ck = ck.at[bidx, slot].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[bidx, slot].set(v[:, 0].astype(cv.dtype))
            cpos = cpos.at[bidx, slot].set(positions[:, 0])
            new_cache = (ck, cv, cpos)
            out = K.attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                              positions, cpos, window=window)
        else:
            # ---- prefill into cache (serving): seq fits the buffer ----
            pad = S - k.shape[1]
            if pad < 0:
                raise ValueError("prefill longer than cache")
            kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            pp = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1)
            new_cache = (kp.astype(ck.dtype), vp.astype(cv.dtype), pp)
            out = K.attention(q, k, v, positions, positions, window=window)
    else:
        # ---- training / encoder: no cache ----
        out = K.attention(q, k, v, positions, positions, window=window,
                          causal=causal)

    out = out.reshape(x.shape[0], x.shape[1], cfg.q_dim)
    out = shard(out @ p["wo"], "batch", None, "act_embed")
    return x + out, new_cache


def mlp_block(p, x, cfg: ModelConfig):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    up = shard(h @ p["wi"], "batch", None, "act_ff")
    gate = shard(h @ p["wg"], "batch", None, "act_ff")
    out = (jax.nn.silu(gate) * up) @ p["wo"]
    return x + shard(out, "batch", None, "act_embed")

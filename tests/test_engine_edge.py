"""Edge cases: engine capacity, enc-dec serving, simulator breakdown."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.cluster import EPDCluster
from repro.core.simulator import SHAREGPT_4O, simulate
from repro.models.model import init_params
from repro.serving.engine import Engine
from repro.serving.request import Request


def test_whisper_epd_serving():
    """Enc-dec (audio) arch through the full disaggregated pipeline."""
    cfg = get_config("whisper-base").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    cluster = EPDCluster(cfg, params, max_batch=2, max_len=48)
    reqs = [Request(prompt_tokens=[1, 2, 3], max_new_tokens=4,
                    mm_payload=b"audio-%d" % i, mm_tokens=0)
            for i in range(2)]
    for r in reqs:
        cluster.submit(r)
    done = cluster.run_until_done()
    assert len(done) == 2
    assert all(len(r.output_tokens) == 4 for r in done)


def test_engine_slot_reuse():
    """Slots free on completion and are reusable for new requests."""
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_len=48)
    for wave in range(3):
        reqs = [Request(prompt_tokens=[5 + wave, 6, 7], max_new_tokens=3)
                for _ in range(2)]
        for r in reqs:
            first, caches = eng.prefill_request(r)
            eng.insert(r, caches, first)
        while eng.n_active:
            eng.decode_step()
        assert all(len(r.output_tokens) == 3 for r in reqs)
    assert eng.free_slots() == [0, 1]


def test_engine_rejects_overlong_prompt():
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=1, max_len=16)
    with pytest.raises(ValueError, match="exceeds"):
        eng.prefill_request(Request(prompt_tokens=list(range(40))))


def test_simulator_stage_breakdown_consistency():
    m = simulate(get_config("openpangu-7b-vl"), "E-P-D", SHAREGPT_4O,
                 rate=4.0, n_requests=96, seed=4)
    b = m.stage_breakdown_ms()
    # decomposition covers TTFT: queue + encode + dispatch + prefill ~ TTFT
    total = b["encode_queue"] + b["encode"] + b["dispatch"] + b["prefill"]
    assert total == pytest.approx(m.mean_ttft_ms, rel=0.02)
    for v in b.values():
        assert v >= 0.0


def test_simulator_replicas_balance_load():
    """2 replicas at 2x the rate should roughly match 1 replica at 1x."""
    model = get_config("openpangu-7b-vl")
    one = simulate(model, "(E-P)-D", SHAREGPT_4O, rate=3.0,
                   n_requests=128, seed=6)
    two = simulate(model, "(E-P)-D", SHAREGPT_4O, rate=6.0,
                   n_requests=128, seed=6, replicas=2)
    assert two.n_chips == 2 * one.n_chips
    # per-chip throughput comparable (within queueing noise)
    t1 = one.throughput_tok_s / one.n_chips
    t2 = two.throughput_tok_s / two.n_chips
    assert t2 == pytest.approx(t1, rel=0.25)

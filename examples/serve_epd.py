"""End-to-end serving driver: compare deployment topologies on a
paper-style workload (ShareGPT-4o trace, openPangu-7B-VL cost model) and
print the Table-5-style summary.

    PYTHONPATH=src python examples/serve_epd.py [--rate 8] [--requests 256]
"""
import argparse

from repro.configs import get_config
from repro.core.simulator import SHAREGPT_4O, simulate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--requests", type=int, default=256)
    args = ap.parse_args()

    model = get_config("openpangu-7b-vl")
    print(f"workload: ShareGPT-4o, {args.requests} requests @ "
          f"{args.rate} req/s total; SLO TTFT<=2000ms TPOT<=50ms\n")
    print(f"{'deployment':10s} {'chips':>5s} {'TTFT ms':>9s} {'TPOT ms':>8s} "
          f"{'SLO %':>6s} {'eff tok/s/chip':>14s}")
    for dep in ["TP1", "TP2", "E-PD", "(E-PD)", "EP-D", "(E-P)-D",
                "(E-D)-P", "E-P-D"]:
        m = simulate(model, dep, SHAREGPT_4O, rate=args.rate,
                     n_requests=args.requests, seed=7)
        print(f"{dep:10s} {m.n_chips:5d} {m.mean_ttft_ms:9.1f} "
              f"{m.mean_tpot_ms:8.2f} {m.slo_attainment(2000, 50)*100:6.1f} "
              f"{m.effective_throughput(2000, 50):14.2f}")
    print("\npaper claims reproduced: decode disaggregation stabilizes "
          "TPOT; (E-D)-P wins TTFT; E-P-D wins SLO at high load.")


if __name__ == "__main__":
    main()

"""Chunked paged prefill: chunked-vs-monolithic greedy-token parity,
streaming-transfer planning (kv_transfer.plan_chunked), cluster overlap
accounting, and the simulator TTFT A/B against the serialized baseline."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.costmodel import RDMA, CostModel
from repro.core.kv_transfer import plan, plan_chunked
from repro.serving.request import Request


@pytest.fixture(scope="module")
def smollm():
    from repro.models.model import init_params
    cfg = get_config("smollm-135m").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, *, chunk=None, prefix=False, max_len=64, page=8,
            **kw):
    from repro.serving.engine import Engine
    return Engine(cfg, params, max_batch=2, max_len=max_len, paged=True,
                  page_size=page, prefix_cache=prefix,
                  chunked_prefill=chunk is not None,
                  prefill_chunk=chunk or 32, **kw)


def _serve(eng, prompt, n=5):
    r = Request(prompt_tokens=list(prompt), max_new_tokens=n)
    f, p = eng.prefill_request(r)
    eng.insert(r, p, f)
    while any(s is r for s in eng.slots):
        eng.decode_step()
    return r.output_tokens


# ---------------------------------------------------------------------------
# parity: chunked == monolithic greedy tokens, all chunk/prompt shapes
# ---------------------------------------------------------------------------

# page = 8, max_len = 64. Prompts cover: inside one page, non-divisible
# by both page and chunk, exactly chunk-divisible, one past a boundary.
PROMPTS = ([5, 6, 7], list(range(2, 22)), list(range(2, 34)),
           list(range(2, 35)))


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_chunked_matches_monolithic_tokens(smollm, chunk):
    """Greedy outputs are byte-identical whether the prompt prefills in
    one shot or in chunks of ``chunk`` tokens: chunk == page, chunk >
    page, non-divisible prompt, prompt < chunk, boundary-exact prompt."""
    cfg, params = smollm
    mono = _engine(cfg, params)
    chunked = _engine(cfg, params, chunk=chunk)
    for prompt in PROMPTS:
        assert _serve(mono, prompt) == _serve(chunked, prompt), \
            (chunk, len(prompt))
        chunked.assert_no_page_leaks()
        mono.assert_no_page_leaks()
    assert chunked.pool.n_free == chunked.pool.n_pages - 1


def test_chunked_with_prefix_cache_matches_cold(smollm):
    """Chunked + radix prefix cache: parity for a chunk boundary inside
    a prefix-cache hit, a CoW divergence mid-page, a miss, an extension,
    and an identical re-run — while computing fewer tokens than cold."""
    cfg, params = smollm
    base = list(range(2, 22))                     # 20 tokens = 2.5 pages
    cold = _engine(cfg, params)
    warm = _engine(cfg, params, chunk=16, prefix=True, n_pool_pages=64)
    assert _serve(cold, base) == _serve(warm, base)       # seed the cache
    probes = (base[:16] + [55, 56],               # hit ends on page edge
              base[:10] + [99, 98, 97],           # CoW inside page 2
              [77, 78, 79, 80],                   # full miss
              base + [30, 31, 32],                # extends past chunk bound
              list(base))                         # identical re-run
    for probe in probes:
        before = warm.prefill_tokens_computed
        assert _serve(cold, probe) == _serve(warm, probe), probe
        hit = warm.prefill_tokens_computed - before < len(probe)
        assert hit == (probe[0] == base[0])
        warm.assert_no_page_leaks()
        cold.assert_no_page_leaks()
    assert warm.prefill_tokens_computed < warm.prefill_tokens_total


def test_chunked_payload_segments_cover_pages(smollm):
    """The payload's streaming segments partition its pages and its
    computed tokens exactly; a cached prefix appears as a leading
    zero-compute segment."""
    cfg, params = smollm
    eng = _engine(cfg, params, chunk=16, prefix=True, n_pool_pages=64)
    prompt = list(range(400, 430))                # 30 tokens -> 2 chunks
    r = Request(prompt_tokens=prompt, max_new_tokens=1)
    f, p = eng.prefill_request(r)
    assert [t for t, _ in p.chunks] == [16, 14]
    assert sum(n for _, n in p.chunks) == len(p.page_ids)
    eng.release_payload(p)
    # warm re-run: 24 of 30 tokens cached (cap len-1 keeps one computed)
    r2 = Request(prompt_tokens=list(prompt), max_new_tokens=1)
    f2, p2 = eng.prefill_request(r2)
    assert f2 == f
    assert p2.cached_tokens > 0
    assert p2.chunks[0][0] == 0                   # cached segment: 0 compute
    assert p2.chunks[0][1] == p2.cached_tokens // eng.page_size
    assert sum(t for t, _ in p2.chunks) == p2.n_tokens - p2.cached_tokens
    assert sum(n for _, n in p2.chunks) == len(p2.page_ids)
    eng.release_payload(p2)
    eng.assert_no_page_leaks()


def test_chunked_validation_and_fallbacks(smollm):
    from repro.serving.engine import Engine
    cfg, params = smollm
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, params, chunked_prefill=True)
    with pytest.raises(ValueError, match="multiple"):
        _engine(cfg, params, chunk=12)            # not a page multiple
    mamba = get_config("mamba2-370m").reduced()
    with pytest.raises(ValueError, match="attention-only"):
        Engine(mamba, None, paged=True, chunked_prefill=True,
               max_len=64, page_size=16, prefill_chunk=16)


def test_chunked_multimodal_falls_back_to_monolithic():
    """Multimodal prompts bypass the chunk loop (mm embeds can't resume
    mid-sequence) but still serve correctly on a chunked engine."""
    from repro.models.model import init_params
    from repro.serving.engine import Engine
    cfg = get_config("llava-next-mistral-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=1, max_len=64, paged=True,
                 page_size=8, chunked_prefill=True, prefill_chunk=16)
    r = Request(prompt_tokens=[5, 6, 7, 8], max_new_tokens=3,
                mm_payload=b"img", mm_tokens=8)
    out = eng.run_request(r)
    assert len(out) == 3
    eng.assert_no_page_leaks()
    # the monolithic fallback produces a segment-less payload
    r2 = Request(prompt_tokens=[5, 6, 7, 8], max_new_tokens=1,
                 mm_payload=b"img", mm_tokens=8)
    import repro.models.frontend as FE
    feats = FE.stub_embeddings(cfg, r2.mm_payload, r2.mm_tokens)[None]
    _, p = eng.prefill_request(r2, feats, None)
    assert p.chunks == []
    eng.release_payload(p)
    eng.assert_no_page_leaks()


def test_failed_chunked_prefill_unwinds_all_refs(smollm, monkeypatch):
    """A device error in any chunk must release the match refs, the CoW
    ref, and every prior chunk's fresh pages."""
    cfg, params = smollm
    base = list(range(2, 22))
    eng = _engine(cfg, params, chunk=8, prefix=True, n_pool_pages=64)
    _serve(eng, base, n=1)
    used = eng.pool.n_used

    calls = {"n": 0}
    real = eng._prefill_suffix

    def boom_on_second(*a, **k):
        calls["n"] += 1
        if calls["n"] >= 2:                       # chunk 0 OK, chunk 1 dies
            raise RuntimeError("injected device OOM")
        return real(*a, **k)

    monkeypatch.setattr(eng, "_prefill_suffix", boom_on_second)
    probe = base[:10] + [99, 98, 97] + list(range(600, 610))  # CoW + 2 chunks
    with pytest.raises(RuntimeError, match="injected"):
        eng.prefill_request(Request(prompt_tokens=probe, max_new_tokens=1))
    assert eng.pool.n_used == used
    eng.assert_no_page_leaks()
    monkeypatch.undo()
    _serve(eng, probe, n=1)                       # retry succeeds cleanly
    eng.assert_no_page_leaks()


# ---------------------------------------------------------------------------
# kv_transfer.plan_chunked: schedule semantics + edges
# ---------------------------------------------------------------------------

def test_plan_chunked_overlap_and_tail():
    """Chunk k ships under chunk k+1's compute; only the last chunk's
    wire time (plus its handshake) is exposed."""
    p = plan_chunked(chunk_bytes=[100e6] * 4, chunk_compute=[0.1] * 4,
                     handshake=1e-3, link_bw=50e9)
    assert p.scheme == "chunked"
    assert len(p.groups) == 4
    for k, g in enumerate(p.groups):
        assert g.t_ready == pytest.approx(0.1 * (k + 1))
        if k < 3:                       # overlaps the next chunk's compute
            assert g.t_done < p.groups[k + 1].t_ready
    assert p.prefill_end == pytest.approx(0.4)
    assert p.exposed_latency == pytest.approx(1e-3 + 100e6 / 50e9)
    # 3 of 4 (handshake + wire) units hide under compute
    assert p.overlap_ratio == pytest.approx(0.75)


def test_plan_chunked_edges():
    # empty chunk: no group, no handshake, but compute advances the clock
    p = plan_chunked(chunk_bytes=[0.0, 8e6, 0.0, 8e6],
                     chunk_compute=[0.0, 0.01, 0.01, 0.01],
                     handshake=1e-3, link_bw=1e9)
    assert len(p.groups) == 2
    assert [g.start for g in p.groups] == [1, 3]
    assert p.kv_latency == pytest.approx(2 * 1e-3 + 2 * 8e6 / 1e9)
    # single-page prompt: one segment, fully exposed past its compute
    q = plan_chunked(chunk_bytes=[4e6], chunk_compute=[0.01],
                     handshake=1e-3, link_bw=1e9)
    assert len(q.groups) == 1
    assert q.total_done == pytest.approx(0.01 + 1e-3 + 4e6 / 1e9)
    assert q.overlap_ratio == pytest.approx(0.0, abs=1e-9)
    # cached-prefix segment (zero compute) ships at t=0, under ALL compute
    r = plan_chunked(chunk_bytes=[4e6, 4e6], chunk_compute=[0.0, 1.0],
                     handshake=1e-3, link_bw=1e9)
    assert r.groups[0].t_ready == 0.0
    assert r.groups[0].t_done < 1.0
    with pytest.raises(ValueError, match="segments"):
        plan_chunked(chunk_bytes=[1.0], chunk_compute=[0.1, 0.1],
                     handshake=0.0, link_bw=1e9)


def test_plan_chunked_final_ragged_chunk_page_rounding():
    """page_bytes rounds every segment (here the ragged tail) up to whole
    pool pages — the wire never ships a partial page."""
    page = 3e6
    p = plan_chunked(chunk_bytes=[9e6, 4e6], chunk_compute=[0.01, 0.01],
                     handshake=1e-3, link_bw=1e9, page_bytes=page)
    assert p.groups[0].nbytes == pytest.approx(9e6)       # already aligned
    assert p.groups[1].nbytes == pytest.approx(6e6)       # 4e6 -> 2 pages
    for g in p.groups:
        assert g.nbytes % page == pytest.approx(0.0, abs=1e-6)


def test_chunked_ttft_beats_serialized_baseline():
    """Acceptance: at >= 4 chunks the streaming schedule's TTFT gate
    (total_done) is strictly below the serialized prefill-then-transfer
    baseline, and the margin grows with prompt length."""
    big = get_config("openpangu-7b-vl")
    cost = CostModel(big, RDMA, page_tokens=16)
    C = 1024
    margins = []
    for L in (4096, 8192, 16384):
        toks = [C] * (L // C) + ([L % C] if L % C else [])
        assert len(toks) >= 4
        per_tok = cost.kv_bytes_per_token()
        ch = plan_chunked(chunk_bytes=[c * per_tok for c in toks],
                          chunk_compute=cost.chunk_prefill_times(L, toks),
                          handshake=cost.hw.handshake,
                          link_bw=cost.hw.link_bw,
                          page_bytes=cost.kv_page_bytes())
        ser = plan("one_shot", n_layers=big.n_layers,
                   bytes_per_layer=cost.kv_bytes(L) / big.n_layers,
                   per_layer_compute=cost.per_layer_prefill_time(L),
                   handshake=cost.hw.handshake, link_bw=cost.hw.link_bw,
                   page_bytes=cost.kv_page_bytes_per_layer())
        assert ch.total_done < ser.total_done, L
        margins.append(ser.total_done - ch.total_done)
    assert margins[-1] > margins[0]


def test_chunk_prefill_times_conserve_monolithic_compute():
    """Chunk times sum to the monolithic prefill plus one launch overhead
    per extra chunk; zero-token (cached) segments cost nothing; later
    chunks cost more (quadratic attention against a longer context)."""
    big = get_config("openpangu-7b-vl")
    cost = CostModel(big)
    L, C = 2048, 512
    toks = [C] * 4
    times = cost.chunk_prefill_times(L, toks)
    mono = cost.prefill_time(L)
    assert sum(times) == pytest.approx(mono + 3 * cost.hw.launch_overhead)
    assert times == sorted(times)
    with_cached = cost.chunk_prefill_times(L, [0] + toks[1:],
                                           cached_prefix=512)
    assert with_cached[0] == 0.0
    assert sum(with_cached) == pytest.approx(
        cost.prefill_time(L, cached_prefix=512)
        + 2 * cost.hw.launch_overhead)


# ---------------------------------------------------------------------------
# cluster: streaming overlap accounting end-to-end
# ---------------------------------------------------------------------------

def test_cluster_chunked_streaming_accounting(smollm):
    """EPDCluster(chunked_prefill=True): same tokens as the plain paged
    cluster, chunked transfer plans with chunk-k shipping before chunk
    k+1 finishes compute, and no leaked pages."""
    from repro.core.cluster import EPDCluster
    cfg, params = smollm

    def run(chunked):
        cl = EPDCluster(cfg, params, max_batch=2, max_len=64, paged=True,
                        page_size=8, chunked_prefill=chunked,
                        prefill_chunk=16)
        reqs = [Request(prompt_tokens=list(range(3, 45 + i)),
                        max_new_tokens=4) for i in range(2)]
        for r in reqs:
            cl.submit(r)
        cl.run_until_done()
        return cl, [r.output_tokens for r in reqs]

    base, outs_b = run(False)
    ch, outs_c = run(True)
    assert outs_b == outs_c
    assert len(ch.report.kv_plans) == 2
    for p in ch.report.kv_plans:
        assert p.scheme == "chunked"
        assert len(p.groups) >= 2
        # chunk k's transfer is in flight before the LAST chunk's compute
        # finishes — the compute/transfer pipeline the scheme exists for
        assert p.groups[0].t_send < p.prefill_end + \
            ch.cost.hw.handshake + 1e-12
        # payloads are page-quantized
        page_bytes = ch.cost.kv_page_bytes()
        for g in p.groups:
            assert g.nbytes % page_bytes == pytest.approx(0.0, abs=1e-6)
    ch.prefill_engine.assert_no_page_leaks()
    ch.decode_engine.assert_no_page_leaks()
    assert ch.prefill_engine.pool.n_used == 0
    assert ch.decode_engine.pool.n_used == 0


# ---------------------------------------------------------------------------
# simulator A/B: chunked mode lowers modeled TTFT at long prompt lengths
# ---------------------------------------------------------------------------

def test_simulator_chunked_lowers_ttft_on_long_prompts():
    from repro.core.simulator import SHAREGPT_4O, simulate
    model = get_config("openpangu-7b-vl")
    ds = dataclasses.replace(SHAREGPT_4O, mm_fraction=0.0,
                             text_tokens_mean=4096.0)
    kw = dict(rate=0.5, n_requests=24, seed=5, kv_page_tokens=16, hw=RDMA)
    ser = simulate(model, "E-P-D", ds, kv_scheme="one_shot", **kw)
    ch = simulate(model, "E-P-D", ds, chunked_prefill=True,
                  prefill_chunk_tokens=1024, **kw)
    assert ch.mean_ttft_ms < ser.mean_ttft_ms
    assert ch.p99_ttft_ms < ser.p99_ttft_ms


def test_simulator_short_prompts_skip_chunking():
    """Prompts that fit in one chunk never pay the chunking overhead:
    the schedule falls back to the configured scheme."""
    from repro.core.simulator import SimConfig, Simulator, gen_requests
    from repro.core.simulator import SHAREGPT_4O
    model = get_config("openpangu-7b-vl")
    ds = dataclasses.replace(SHAREGPT_4O, mm_fraction=0.0,
                             text_tokens_mean=32.0)
    cfg = SimConfig(deployment="E-P-D", chunked_prefill=True,
                    prefill_chunk_tokens=4096)
    sim = Simulator(model, cfg)
    sim.run(gen_requests(ds, 16, rate=2.0, seed=1))
    assert sim.kv_plans
    assert all(p.scheme == "grouped" for p in sim.kv_plans)

"""Jitted per-instance step functions: encode / prefill / decode / insert.

These are the *real-compute* building blocks used by the serving engine
(CPU-scale configs) and by the dry-run (full-scale configs lowered on the
production meshes).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import decode_forward, prefill_forward
from repro.serving.sampling import sample


def make_prefill_fn(cfg: ModelConfig, donate_caches: bool = False,
                    prefix: bool = False):
    """Jitted prefill step.

    donate_caches=True is the PAGED variant: ``caches`` is a hybrid
    pytree — the engine's pool entries under "attn" (donated, so the
    page scatter is an in-place write, not a pool copy), a fresh
    batch-1 side state for "ssm"/"cross"/"len", and the request's
    staging block-table row under "pages".

    prefix=True is the SUFFIX variant over that paged pytree: ``tokens``
    holds only the uncached tail of the prompt (from the page-aligned
    ``pos_base``; entries before ``prefix_len`` are dummies) and the
    block-table row already maps the shared prefix pages, so prefill
    computes O(suffix) instead of O(prompt). Traces once per padded
    suffix-page bucket.

    The same suffix step is the CHUNK step of chunked prefill
    (Engine._prefill_chunked): chunk *k* calls it with ``pos_base`` = the
    chunk's page-aligned start, ``prefix_len`` = tokens already resident
    in pool pages (chunks 0..k-1 plus any cached prefix), and
    ``lengths`` = the chunk's END — so window positions past the chunk
    are dummies (masked scatter, position -1) that a later chunk will
    compute. Intermediate chunks share one fixed-size trace bucket; only
    the final ragged window adds one.
    """

    if prefix:

        @functools.partial(jax.jit, donate_argnums=(3,))
        def prefill_suffix_fn(params, tokens, lengths, caches, prefix_len,
                              pos_base, mm_feats=None, mm_start=None):
            logits, new_caches = prefill_forward(
                params, cfg, tokens, caches, lengths=lengths,
                prefix_len=prefix_len, pos_base=pos_base,
                mm_feats=mm_feats, mm_start=mm_start)
            return logits, new_caches

        return prefill_suffix_fn

    @functools.partial(jax.jit,
                       donate_argnums=(3,) if donate_caches else ())
    def prefill_fn(params, tokens, lengths, caches, mm_embeds=None,
                   enc_frames=None, mm_feats=None, mm_start=None):
        logits, new_caches = prefill_forward(
            params, cfg, tokens, caches, lengths=lengths,
            mm_embeds=mm_embeds, enc_frames=enc_frames,
            mm_feats=mm_feats, mm_start=mm_start)
        return logits, new_caches

    return prefill_fn


def make_encode_fn(cfg: ModelConfig):
    """Jitted Encode-stage forward: stub patch/frame embeddings through
    the real learned projector -> d_model-wide feature tensor, the E->P
    payload landed in the MM Store. Float32 output so store round-trips
    (and recompute on the Prefill side) stay bit-identical."""

    @jax.jit
    def encode_fn(params, patches):
        feats = patches.astype(params["projector"].dtype) \
            @ params["projector"]
        return feats.astype(jnp.float32)

    return encode_fn


def make_decode_fn(cfg: ModelConfig, temperature: float = 0.0):
    @functools.partial(jax.jit, donate_argnums=(2,))
    def decode_fn(params, tokens, caches, key):
        logits, new_caches = decode_forward(params, cfg, tokens, caches)
        next_tok = sample(logits, key, temperature)
        return next_tok, new_caches

    return decode_fn


def make_paged_insert_fn(cfg: ModelConfig):
    """Attach a prefilled request to slot ``slot`` of a PAGED decode cache.

    The attention KV is NOT touched — its pages are already in the pool
    (same-engine handoff) or were copied by ``make_page_copy_fn``; this
    only writes the slot's block-table row, length, and the small
    slot-indexed side state (SSM state, cross-KV).
    """

    @functools.partial(jax.jit, donate_argnums=(1,), static_argnums=(3,))
    def insert_fn(side, dst_caches, table_row, slot: int):
        def ins(dst, src):
            if dst.ndim == 1:
                return dst.at[slot].set(src[0])
            if src.ndim >= 3 and src.shape[2] != dst.shape[2]:
                pad = [(0, 0)] * src.ndim
                pad[2] = (0, dst.shape[2] - src.shape[2])
                fill = -1 if src.dtype == jnp.int32 else 0
                src = jnp.pad(src, pad, constant_values=fill)
            return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))

        out = dict(dst_caches)
        out["ssm"] = jax.tree.map(ins, dst_caches["ssm"], side["ssm"])
        if dst_caches["cross"] is not None:
            out["cross"] = jax.tree.map(ins, dst_caches["cross"],
                                        side["cross"])
        out["len"] = dst_caches["len"].at[slot].set(side["len"][0])
        out["pages"] = dst_caches["pages"].at[slot].set(table_row)
        return out

    return insert_fn


def make_page_copy_fn():
    """Cross-engine P->D page movement: gather the request's pages from
    the source pool, scatter into the destination pool's allocated pages.
    O(one request's pages) — never touches the rest of either pool."""

    @functools.partial(jax.jit, donate_argnums=(1,))
    def copy_fn(src_attn, dst_attn, src_ids, dst_ids):
        def cp(dst, src):
            return dst.at[:, dst_ids].set(src[:, src_ids].astype(dst.dtype))

        return jax.tree.map(cp, dst_attn, src_attn)

    return copy_fn


def make_page_gather_fn():
    """Pull a set of physical pages out of the pool: the device side of
    preemption swap-out. Returns the gathered page KV (all layers) for
    the caller to move to the host backing store."""

    @jax.jit
    def gather_fn(attn, ids):
        def g(pool):
            return pool[:, ids]

        return jax.tree.map(g, attn)

    return gather_fn


def make_page_scatter_fn():
    """Write previously-swapped page KV back into freshly-allocated pool
    pages: the device side of preemption swap-in (re-fault)."""

    @functools.partial(jax.jit, donate_argnums=(0,))
    def scatter_fn(attn, data, ids):
        def s(pool, d):
            return pool.at[:, ids].set(d.astype(pool.dtype))

        return jax.tree.map(s, attn, data)

    return scatter_fn


def make_pool_page_copy_fn():
    """Same-pool page duplication: the copy-on-write step of the prefix
    cache. Copies each ``src_ids[i]`` page onto ``dst_ids[i]`` within one
    engine's pool so a request diverging inside a shared, partially
    matched page writes its own private copy instead of the shared page."""

    @functools.partial(jax.jit, donate_argnums=(0,))
    def copy_fn(attn, src_ids, dst_ids):
        def cp(pool):
            return pool.at[:, dst_ids].set(pool[:, src_ids])

        return jax.tree.map(cp, attn)

    return copy_fn


def make_insert_fn(cfg: ModelConfig):
    """Copy one request's prefilled cache (batch=1) into batch slot `slot`
    of the decode cache — the P->D handoff on the Decode instance."""

    @functools.partial(jax.jit, donate_argnums=(1,), static_argnums=(2,))
    def insert_fn(src_caches, dst_caches, slot: int):
        def ins(dst, src):
            if dst.ndim == 1:                       # lengths (B,)
                return dst.at[slot].set(src[0])
            # stacked caches: (R, B, ...) — batch axis 1
            if src.ndim >= 3 and src.shape[2] != dst.shape[2]:
                cfgpad = [(0, 0)] * src.ndim
                cfgpad[2] = (0, dst.shape[2] - src.shape[2])
                fill = -1 if src.dtype == jnp.int32 else 0
                src = jnp.pad(src, cfgpad, constant_values=fill)
            return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))

        return jax.tree.map(ins, dst_caches, src_caches)

    return insert_fn

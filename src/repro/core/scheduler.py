"""Modality-aware multi-path scheduling + instance-level load balancing
(paper §3.4).

The Router keeps a global instance status table (queue length, pending
work, busy-until estimates) updated by the simulator / engines, routes
multimodal requests down the E->P->D path and text-only requests down the
P->D path, and dispatches each stage task to the least-loaded instance
serving that stage.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.deployment import Deployment, InstanceSpec
from repro.serving.request import Request


@dataclass
class InstanceStatus:
    spec: InstanceSpec
    queue_len: int = 0             # tasks waiting (all stages)
    active_decode: int = 0         # requests in the decode batch
    pending_tokens: float = 0.0    # queued prompt tokens (work estimate)
    busy_until: float = 0.0        # latest known completion estimate

    def load(self, now: float) -> float:
        """Scalar load metric for least-loaded-first dispatch."""
        backlog = max(0.0, self.busy_until - now)
        return (backlog + 1e-3 * self.pending_tokens
                + 0.01 * self.queue_len + 0.002 * self.active_decode)


class Router:
    def __init__(self, deployment: Deployment):
        self.deployment = deployment
        self.status: Dict[str, InstanceStatus] = {
            i.name: InstanceStatus(i) for i in deployment.instances}

    # -- multi-path routing ----------------------------------------------------
    def path(self, req: Request) -> List[str]:
        """Stage path for a request: E->P->D for multimodal, P->D for text."""
        return ["E", "P", "D"] if req.is_multimodal else ["P", "D"]

    def pick(self, stage: str, now: float,
             prefer: Optional[str] = None) -> InstanceStatus:
        """Least-loaded instance serving `stage`. ``prefer`` pins affinity
        (e.g. keep P and D on the same instance when it serves both)."""
        cands = [self.status[i.name]
                 for i in self.deployment.stage_instances(stage)]
        if not cands:
            raise ValueError(
                f"deployment {self.deployment.name} has no {stage} instance")
        if prefer is not None:
            for c in cands:
                if c.spec.name == prefer:
                    return c
        return min(cands, key=lambda c: c.load(now))

    # -- status updates (called by the execution layer) --------------------------
    def on_enqueue(self, name: str, tokens: float = 0.0) -> None:
        st = self.status[name]
        st.queue_len += 1
        st.pending_tokens += tokens

    def on_start(self, name: str, tokens: float = 0.0) -> None:
        st = self.status[name]
        st.queue_len = max(0, st.queue_len - 1)
        st.pending_tokens = max(0.0, st.pending_tokens - tokens)

    def on_busy_until(self, name: str, t: float) -> None:
        st = self.status[name]
        st.busy_until = max(st.busy_until, t)

    def on_decode_join(self, name: str) -> None:
        self.status[name].active_decode += 1

    def on_decode_leave(self, name: str) -> None:
        st = self.status[name]
        st.active_decode = max(0, st.active_decode - 1)

"""Paged KV-cache page pool (vLLM-style block allocator).

The device-side KV pool is a flat array of fixed-size pages shared by
every decode slot: ``(n_repeats, n_pages, page_size, n_kv, head_dim)``
per attention pattern position (see ``layers.PagedAttnCache``). This
module is the HOST-side bookkeeping around it:

* :class:`PagePool` — a free-list allocator over physical page ids.
  Physical page 0 is reserved as the *trash page*: unmapped block-table
  entries point at it, so decode writes from inactive slots and prefill
  writes past a request's last page land somewhere harmless instead of
  corrupting live pages.
* :class:`PagedKVPayload` — the P->D handoff unit. Instead of a full
  cache pytree it names the request's physical pages in the *source*
  engine's pool plus the small per-slot side state (SSM state, cross-KV,
  length). Inserting into the same engine is a pure block-table update
  (zero KV bytes moved); inserting into another engine gathers/scatters
  only those pages — O(one request's pages), never O(pool).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Sequence

import numpy as np

TRASH_PAGE = 0


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` (at least one)."""
    return max(1, -(-int(n_tokens) // page_size))


class PagePool:
    """Free-list allocator over the physical pages of one engine's pool.

    Page ids are ints in [1, n_pages); page 0 is the reserved trash page
    and is never handed out.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need n_pages >= 2 (page 0 is reserved)")
        if page_size < 1:
            raise ValueError("page_size must be positive")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        # LIFO free list: recently freed pages are re-used first (their
        # contents are most likely still resident in cache hierarchies).
        self._free: List[int] = list(range(n_pages - 1, 0, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return pages_for(n_tokens, self.page_size)

    def alloc(self, n: int) -> np.ndarray:
        """Pop ``n`` physical page ids; raises RuntimeError when exhausted."""
        if n > len(self._free):
            raise RuntimeError(
                f"KV page pool exhausted: requested {n} pages, "
                f"{len(self._free)}/{self.n_pages - 1} free")
        out = self._free[-n:][::-1]
        del self._free[-n:]
        return np.asarray(out, np.int32)

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            p = int(p)
            if p == TRASH_PAGE:
                raise ValueError("cannot free the reserved trash page")
            if not (0 < p < self.n_pages):
                raise ValueError(f"page id {p} out of range")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
            self._free.append(p)


@dataclass
class PagedKVPayload:
    """One prefilled request's KV, by reference into the source pool.

    source    — the Engine whose pool holds the pages.
    page_ids  — (n_pages,) physical ids in the source pool, in sequence
                order (page j holds tokens [j*page, (j+1)*page)).
    n_tokens  — true KV length (prompt + multimodal tokens).
    side      — batch-1 slot state pytree: {"ssm", "cross", "len"}.
    kv_nbytes — attention-KV bytes these pages occupy across all layers
                (what a cross-engine insert actually moves).
    """

    source: Any
    page_ids: np.ndarray
    n_tokens: int
    side: Dict[str, Any] = field(default_factory=dict)
    kv_nbytes: int = 0

    @property
    def n_pages(self) -> int:
        return len(self.page_ids)

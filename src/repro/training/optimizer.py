"""AdamW in pure JAX (no optax dependency)."""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


class AdamW(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p)
        return AdamWState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))

    def schedule(self, step):
        warm = jnp.minimum(1.0, (step + 1) / self.warmup_steps)
        return self.lr * warm

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

        step = state.step + 1
        lr = self.schedule(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                          state.nu, grads)

        def upd(p, m, v):
            mhat = m / b1c
            vhat = v / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps) + \
                self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step, mu, nu)

"""Paged KV-cache page pool (vLLM-style block allocator, ref-counted).

The device-side KV pool is a flat array of fixed-size pages shared by
every decode slot: ``(n_repeats, n_pages, page_size, n_kv, head_dim)``
per attention pattern position (see ``layers.PagedAttnCache``). This
module is the HOST-side bookkeeping around it:

* :class:`PagePool` — a ref-counted free-list allocator over physical
  page ids. ``alloc`` hands out pages at refcount 1; ``ref`` adds a
  holder (prefix sharing: the radix tree and every request retaining a
  shared prompt page each hold one ref); ``free``/``unref`` drops one
  and returns the page to the free list only when the last holder lets
  go. Physical page 0 is reserved as the *trash page*: unmapped
  block-table entries point at it, so decode writes from inactive slots
  and prefill writes past a request's last page land somewhere harmless
  instead of corrupting live pages.
* :class:`PagedKVPayload` — the P->D handoff unit. Instead of a full
  cache pytree it names the request's physical pages in the *source*
  engine's pool plus the small per-slot side state (SSM state, cross-KV,
  length). Inserting into the same engine is a pure block-table update
  (zero KV bytes moved); inserting into another engine gathers/scatters
  only those pages — O(one request's pages), never O(pool). Payload
  pages may be shared (prefix-cache hits): the payload holds ONE ref per
  page, released on insert-into-another-engine or ``release_payload``.

* :class:`SwapHandle` — the preemption unit. ``swap_out`` moves a set of
  pages' *contents* to a host-side (``np``) backing store and returns the
  pages to the free list; the handle is the ticket that gets them back.

  Swap-handle lifecycle: a handle is born in ``swap_out`` (the caller —
  the engine preempting a decode slot — gathers the pages' KV off the
  device and hands it over together with its page refs). From then on
  exactly one of two things consumes it: ``swap_in`` (re-fault: allocates
  the same number of fresh device pages, pops the host copy and returns
  both so the caller can scatter the KV back — on ``PoolExhausted`` the
  handle stays valid and retryable) or ``swap_free`` (the preempted
  request was abandoned; the host copy is dropped). A handle that is
  never consumed is a leak: ``assert_balanced(swap_handles=...)`` checks
  the outstanding handle set against the preempted requests the caller
  knows about, exactly like device pages are checked against holders.

Leak auditing: ``assert_balanced`` cross-checks the allocator against
the holders the caller believes exist (slots, radix-tree retentions,
swap handles of preempted requests) — engine/cluster tests call it
after draining.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.faults import SITE_SWAP_IN, FaultInjector, SwapLost
from repro.core.telemetry import MetricsRegistry

TRASH_PAGE = 0


class PoolExhausted(RuntimeError):
    """Typed pool-exhaustion error: ``requested`` pages were asked for
    with only ``n_free`` free. Subclasses RuntimeError so pre-existing
    callers keep working; new callers (the preemption trigger, tests)
    can catch/assert on the type instead of string-matching. Note that
    ``n_free`` counts pages on the free list — a pool can be "full"
    while most pages are merely retained by the prefix tree or shared
    by other requests (fragmented-by-refs), which is exactly the state
    preemption and tree eviction reclaim from."""

    def __init__(self, requested: int, n_free: int, n_usable: int):
        self.requested = int(requested)
        self.n_free = int(n_free)
        self.n_usable = int(n_usable)
        super().__init__(
            f"KV page pool exhausted: requested {requested} pages, "
            f"{n_free}/{n_usable} free")


@dataclass(frozen=True)
class SwapHandle:
    """Ticket for pages swapped out to the pool's host backing store.

    ``handle_id`` indexes the pool's store; ``n_pages`` is what
    ``swap_in`` will re-allocate. The handle carries no KV itself — the
    host copy lives in the pool — so it is safe to stash on a preempted
    request and to audit by identity."""

    handle_id: int
    n_pages: int


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` (at least one)."""
    return max(1, -(-int(n_tokens) // page_size))


class PagePool:
    """Ref-counted allocator over the physical pages of one engine's pool.

    Page ids are ints in [1, n_pages); page 0 is the reserved trash page
    and is never handed out. A page is *used* while any holder refs it;
    ``_refs`` doubles as the O(1) membership check that used to scan the
    free list (the old O(n^2) double-free check).
    """

    def __init__(self, n_pages: int, page_size: int,
                 injector: Optional[FaultInjector] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 name: str = "pool"):
        if n_pages < 2:
            raise ValueError("need n_pages >= 2 (page 0 is reserved)")
        if page_size < 1:
            raise ValueError("page_size must be positive")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        # occupancy gauges + high-water mark live in the (possibly
        # shared) metrics registry, labeled by the owning engine's name;
        # `peak_used` stays readable under its historical attribute name
        # via the property below.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_used = self.metrics.gauge("pool_used_pages", pool=name)
        self._m_occ = self.metrics.gauge("pool_occupancy", pool=name)
        self._m_peak = self.metrics.gauge("pool_peak_used_pages", pool=name)
        # fault plane for the host swap tier (SITE_SWAP_IN); a private
        # empty-plan injector means swap_in never faults.
        self.injector = injector if injector is not None else FaultInjector()
        self.swap_lost_total = 0
        # LIFO free list: recently freed pages are re-used first (their
        # contents are most likely still resident in cache hierarchies).
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._refs: Dict[int, int] = {}
        # host-side swap space: handle_id -> (n_pages, host KV pytree).
        # Contents parked here have no device pages; SwapHandle is the
        # only way back in (see the module docstring for the lifecycle).
        self._swap: Dict[int, Tuple[int, Any]] = {}
        self._handle_seq = itertools.count(1)
        self.swapped_out_pages_total = 0
        self.swapped_in_pages_total = 0

    @property
    def peak_used(self) -> int:
        """High-water mark of used pages (benchmarks: chunked-prefill
        memory accounting). Backed by the registry gauge."""
        return int(self._m_peak.value)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def _track_occupancy(self) -> None:
        used = self.n_used
        self._m_used.set(used)
        self._m_occ.set(used / max(1, self.n_pages - 1))
        self._m_peak.max(used)

    def pages_for(self, n_tokens: int) -> int:
        return pages_for(n_tokens, self.page_size)

    def refcount(self, page: int) -> int:
        return self._refs.get(int(page), 0)

    def alloc(self, n: int) -> np.ndarray:
        """Pop ``n`` physical page ids at refcount 1; raises
        :class:`PoolExhausted` (a RuntimeError) when the free list is
        shorter than ``n``."""
        if n <= 0:
            return np.zeros((0,), np.int32)
        if n > len(self._free):
            raise PoolExhausted(n, len(self._free), self.n_pages - 1)
        out = self._free[-n:][::-1]
        del self._free[-n:]
        for p in out:
            self._refs[p] = 1
        self._track_occupancy()
        return np.asarray(out, np.int32)

    def ref(self, pages: Sequence[int]) -> None:
        """Add one holder to each (already-allocated) page."""
        for p in pages:
            p = int(p)
            if p not in self._refs:
                raise ValueError(f"ref of unallocated page {p}")
            self._refs[p] += 1

    def free(self, pages: Sequence[int]) -> None:
        """Drop one holder per page; a page returns to the free list when
        its last holder releases it (``unref`` is an alias)."""
        for p in pages:
            p = int(p)
            if p == TRASH_PAGE:
                raise ValueError("cannot free the reserved trash page")
            if not (0 < p < self.n_pages):
                raise ValueError(f"page id {p} out of range")
            if p not in self._refs:
                raise ValueError(f"double free of page {p}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                self._free.append(p)
        self._track_occupancy()

    unref = free

    # -- host swap space (page-level preemption) ------------------------------

    @property
    def n_swapped_pages(self) -> int:
        """Pages whose contents currently live in the host backing store."""
        return sum(n for n, _ in self._swap.values())

    def swap_out(self, pages: Sequence[int], data: Any = None) -> SwapHandle:
        """Park ``pages``' contents in the host backing store.

        ``data`` is the gathered page KV (any host pytree — the caller
        owns the device->host copy; bookkeeping-only users may pass
        None). Drops ONE ref per page — the caller's holdership moves
        from the device pages to the returned handle — so a page shared
        with other holders (prefix tree, other slots) survives on
        device while this caller's private pages return to the free
        list. See the module docstring for the handle lifecycle."""
        pages = [int(p) for p in pages]
        if not pages:
            raise ValueError("swap_out of an empty page set")
        h = SwapHandle(next(self._handle_seq), len(pages))
        self.free(pages)               # validates refs; raises before store
        self._swap[h.handle_id] = (len(pages), data)
        self.swapped_out_pages_total += len(pages)
        return h

    def swap_in(self, handle: SwapHandle) -> Tuple[np.ndarray, Any]:
        """Re-fault a swapped set: allocate ``handle.n_pages`` fresh
        device pages (refcount 1) and pop the host copy. Returns
        ``(new_page_ids, data)`` for the caller to scatter back. On
        :class:`PoolExhausted` the handle remains valid and retryable;
        on success it is consumed and must not be reused."""
        if handle.handle_id not in self._swap:
            raise ValueError(f"unknown or already-consumed swap "
                             f"handle {handle.handle_id}")
        if self.injector.should_fail(SITE_SWAP_IN, key=handle.handle_id):
            # host swap tier lost the contents: the entry is gone for
            # good (the handle is consumed — there is nothing to retry
            # against), so the caller must take the suffix-recompute
            # arm. Raised BEFORE alloc: no device pages were taken.
            del self._swap[handle.handle_id]
            self.swap_lost_total += 1
            raise SwapLost(handle.handle_id, handle.n_pages)
        ids = self.alloc(handle.n_pages)       # may raise: handle intact
        _, data = self._swap.pop(handle.handle_id)
        self.swapped_in_pages_total += handle.n_pages
        return ids, data

    def swap_free(self, handle: SwapHandle) -> None:
        """Drop a swapped set without re-faulting it (the preempted
        request was abandoned). Idempotence is NOT provided — freeing a
        consumed handle raises, matching the double-free check."""
        if handle.handle_id not in self._swap:
            raise ValueError(f"double free of swap handle "
                             f"{handle.handle_id}")
        del self._swap[handle.handle_id]

    def assert_balanced(self, holders: Iterable[Sequence[int]] = (),
                        swap_handles: Iterable[SwapHandle] = ()) -> None:
        """Leak assertion: the allocator's view must match the holders the
        caller knows about (each element of ``holders`` is one holder's
        page-id list — a slot's block-table row, a payload, the radix
        tree's retained pages), and the host swap store must match the
        ``swap_handles`` the caller knows about (the preempted requests'
        tickets). Raises AssertionError on any leaked page, ref-count
        mismatch, free-list corruption, or leaked/dangling swap entry."""
        expect: Dict[int, int] = {}
        for h in holders:
            for p in h:
                p = int(p)
                if p != TRASH_PAGE:
                    expect[p] = expect.get(p, 0) + 1
        assert len(self._free) + len(self._refs) == self.n_pages - 1, (
            f"pool accounting broken: {len(self._free)} free + "
            f"{len(self._refs)} used != {self.n_pages - 1}")
        assert len(set(self._free)) == len(self._free), \
            "free list contains duplicates"
        assert not (set(self._free) & set(self._refs)), \
            "page both free and referenced"
        leaked = {p: r for p, r in self._refs.items() if p not in expect}
        assert not leaked, f"leaked pages (refs with no holder): {leaked}"
        for p, want in expect.items():
            got = self._refs.get(p, 0)
            assert got == want, (
                f"page {p}: {got} refs but {want} holders")
        expect_swap = {}
        for h in swap_handles:
            assert h.handle_id not in expect_swap, \
                f"swap handle {h.handle_id} claimed twice"
            expect_swap[h.handle_id] = h.n_pages
        got_swap = {hid: n for hid, (n, _) in self._swap.items()}
        leaked_swap = {h: n for h, n in got_swap.items()
                       if h not in expect_swap}
        assert not leaked_swap, (
            f"leaked swap entries (no preempted holder): {leaked_swap}")
        for hid, want in expect_swap.items():
            assert hid in got_swap, (
                f"dangling swap handle {hid}: holder exists but the "
                f"host store has no entry (consumed or never created)")
            assert got_swap[hid] == want, (
                f"swap handle {hid}: store holds {got_swap[hid]} pages "
                f"but the handle claims {want}")


@dataclass
class PagedKVPayload:
    """One prefilled request's KV, by reference into the source pool.

    source        — the Engine whose pool holds the pages.
    page_ids      — (n_pages,) physical ids in the source pool, in sequence
                    order (page j holds tokens [j*page, (j+1)*page)). Pages
                    shared via the prefix cache appear here too; the payload
                    owns one ref on every listed page.
    n_tokens      — true KV length (prompt + multimodal tokens).
    side          — batch-1 slot state pytree: {"ssm", "cross", "len"}.
    kv_nbytes     — attention-KV bytes these pages occupy across all layers
                    (what a cross-engine insert actually moves).
    cached_tokens — prompt tokens served from the prefix cache (prefill
                    computed only the remaining suffix).
    chunks        — streaming segments of a CHUNKED prefill, in order:
                    (computed_tokens, n_pages) per segment. A leading
                    (0, n) entry is the cached-prefix segment (ready
                    before any compute). Empty for monolithic prefill.
                    Sum of n_pages == len(page_ids); the transfer
                    planner uses it to ship segment k while segment k+1
                    computes (kv_transfer.plan_chunked).
    """

    source: Any
    page_ids: np.ndarray
    n_tokens: int
    side: Dict[str, Any] = field(default_factory=dict)
    kv_nbytes: int = 0
    cached_tokens: int = 0
    chunks: List[tuple] = field(default_factory=list)

    @property
    def n_pages(self) -> int:
        return len(self.page_ids)

"""Property-based tests (hypothesis) for system invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import assume, given, settings

from repro.core.costmodel import V5E, CostModel
from repro.core.deployment import parse, scale
from repro.core.kv_transfer import choose_group_size, plan
from repro.core.mm_store import MMStore
from repro.configs import get_config

CFG = get_config("openpangu-7b-vl")


# ---------------------------------------------------------------------------
# KV transfer planner
# ---------------------------------------------------------------------------

plan_params = dict(
    n_layers=st.integers(1, 80),
    bpl=st.floats(1e3, 1e9),
    t_c=st.floats(1e-5, 1.0),
    handshake=st.floats(0.0, 0.1),
    bw=st.floats(1e8, 1e11),
)


@settings(max_examples=200, deadline=None)
@given(**plan_params)
def test_plan_invariants(n_layers, bpl, t_c, handshake, bw):
    for scheme in ("one_shot", "layer_wise", "grouped"):
        p = plan(scheme, n_layers=n_layers, bytes_per_layer=bpl,
                 per_layer_compute=t_c, handshake=handshake, link_bw=bw)
        # full coverage, contiguous, payload conserved
        assert p.groups[0].start == 0
        assert p.groups[-1].end == n_layers
        for g1, g2 in zip(p.groups, p.groups[1:]):
            assert g1.end == g2.start
        assert sum(g.nbytes for g in p.groups) == pytest.approx(
            n_layers * bpl, rel=1e-6)
        # causality: nothing ships before it exists; link never overlaps
        for g in p.groups:
            assert g.t_send >= g.t_ready - 1e-9
            assert g.t_done >= g.t_send
        for g1, g2 in zip(p.groups, p.groups[1:]):
            assert g2.t_done >= g1.t_done - 1e-9
        # metrics in range
        assert 0.0 <= p.overlap_ratio <= 1.0 + 1e-9
        assert p.exposed_latency >= -1e-9
        assert p.effective_bandwidth <= bw * (1 + 1e-9)


@settings(max_examples=200, deadline=None)
@given(**plan_params)
def test_async_grouped_g1_dominates_layer_wise(n_layers, bpl, t_c,
                                               handshake, bw):
    """In the compute-dominant regime (t_c >= t_x + h: a layer's compute
    covers its own transfer AND handshake) async grouped transmission at
    group_size=1 strictly dominates layer-wise: it removes n*h of compute
    stalls and the link still keeps pace.

    Deliberately regime-restricted: at the wire/compute boundary the
    schemes differ only in where the handshake sits (compute stream vs
    link), and whichever stream is saturated loses — hypothesis found
    those crossovers (documented in EXPERIMENTS.md §Perf)."""
    assume(t_c >= bpl / bw + handshake)
    lw = plan("layer_wise", n_layers=n_layers, bytes_per_layer=bpl,
              per_layer_compute=t_c, handshake=handshake, link_bw=bw)
    gr = plan("grouped", n_layers=n_layers, bytes_per_layer=bpl,
              per_layer_compute=t_c, handshake=handshake, link_bw=bw,
              group_size=1)
    tol = 1e-3 * max(1.0, lw.total_done)
    assert gr.total_done <= lw.total_done + tol
    assert gr.exposed_latency <= lw.exposed_latency + tol


@settings(max_examples=200, deadline=None)
@given(**plan_params)
def test_grouped_dominates_in_paper_regime(n_layers, bpl, t_c,
                                           handshake, bw):
    """The paper's operating regime (Table 4): prefill compute dominates
    the per-layer wire time (t_c > t_x) and a keep-up group size exists.
    There the grouped scheme's EXPOSED latency is bounded by one
    handshake + the tapered tail transfer, while layer-wise pays a
    handshake stall per layer — grouped must dominate on exposure and
    effective bandwidth."""
    import math
    t_x = bpl / bw
    assume(t_c > t_x and n_layers >= 4)
    g_req = math.ceil(handshake / max(t_c - t_x, 1e-12))
    assume(g_req <= n_layers // 2)
    lw = plan("layer_wise", n_layers=n_layers, bytes_per_layer=bpl,
              per_layer_compute=t_c, handshake=handshake, link_bw=bw)
    gr = plan("grouped", n_layers=n_layers, bytes_per_layer=bpl,
              per_layer_compute=t_c, handshake=handshake, link_bw=bw)
    tol = 1e-6 * max(1.0, lw.total_done)
    assert gr.exposed_latency <= lw.exposed_latency + tol
    assert gr.overlap_ratio >= lw.overlap_ratio - 1e-6
    assert gr.effective_bandwidth >= lw.effective_bandwidth * (1 - 1e-3)


@settings(max_examples=100, deadline=None)
@given(n_layers=st.integers(1, 100), t_c=st.floats(1e-6, 1.0),
       h=st.floats(0.0, 1.0), t_x=st.floats(1e-9, 1.0))
def test_group_size_bounds(n_layers, t_c, h, t_x):
    g = choose_group_size(n_layers, t_c, h, t_x)
    assert 1 <= g <= n_layers


# ---------------------------------------------------------------------------
# MM store
# ---------------------------------------------------------------------------

@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 20), st.integers(1, 200)),
                min_size=1, max_size=100),
       st.integers(100, 2000))
def test_store_capacity_invariant(ops, cap):
    s = MMStore(capacity_bytes=cap)
    for key, nbytes in ops:
        s.put(f"k{key}", key, nbytes)
        # capacity respected (when more than one entry exists)
        if len(s) > 1:
            assert s.stats.bytes_stored <= cap
        # stored value is the one put
        got = s.get(f"k{key}", record=False)
        assert got is None or got == key


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 10), min_size=1, max_size=50))
def test_store_hit_rate_bounds(keys):
    s = MMStore()
    for k in keys:
        if s.get(f"k{k}") is None:
            s.put(f"k{k}", k, 10)
    assert 0.0 <= s.stats.hit_rate <= 1.0
    assert s.stats.hits + s.stats.misses == len(keys)


# ---------------------------------------------------------------------------
# deployment parsing
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.integers(1, 5))
def test_scale_preserves_stage_coverage(k):
    for name in ("E-P-D", "(E-P)-D", "(E-PD)", "EP-D"):
        dep = scale(parse(name), k)
        assert dep.n_chips == parse(name).n_chips * k
        for stage in "EPD":
            assert len(dep.stage_instances(stage)) >= k


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.integers(1, 16_000), st.integers(1, 16_001))
def test_prefill_monotone_in_len(a, b):
    cm = CostModel(CFG)
    lo, hi = sorted((a, b))
    assert cm.prefill_time(lo) <= cm.prefill_time(hi) + 1e-12


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 512), st.integers(1, 513), st.integers(1, 100_000))
def test_decode_monotone_in_batch(a, b, kv):
    cm = CostModel(CFG)
    lo, hi = sorted((a, b))
    assert cm.decode_step_time(lo, kv) <= cm.decode_step_time(hi, kv) + 1e-12


# ---------------------------------------------------------------------------
# Router pending-token ledger conservation
# ---------------------------------------------------------------------------

from repro.core.scheduler import Router  # noqa: E402

from conftest import hyp_max_examples  # noqa: E402


@settings(max_examples=hyp_max_examples(80), deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5),          # request id
                          st.sampled_from(["enqueue", "start", "progress"]),
                          st.integers(1, 64)),        # token amount
                min_size=1, max_size=60))
def test_router_pending_tokens_conserve_to_zero(ops):
    """Under ARBITRARY interleavings of rid-tagged enqueue / start /
    progress — including double-retirement attempts (start with full
    tokens AND chunked progress), progress before start, and repeated
    events — pending_tokens exactly equals the sum of what each request
    enqueued minus what it legitimately retired, and retiring
    everything drives it to 0 with an empty ledger."""
    dep = parse("E-P-D")
    r = Router(dep)
    name = dep.stage_instances("P")[0].name
    enqueued: dict = {}
    for rid_n, op, tok in ops:
        rid = f"r{rid_n}"
        if op == "enqueue":
            r.on_enqueue(name, float(tok), rid=rid)
            enqueued[rid] = enqueued.get(rid, 0.0) + tok
        elif op == "start":
            r.on_start(name, float(tok), rid=rid)
        else:
            r.on_prefill_progress(name, float(tok), rid=rid)
    st = r.status[name]
    # the ledger IS the aggregate: no request can be over-retired
    assert st.pending_tokens == pytest.approx(
        sum(st.pending_by_req.values()))
    assert st.pending_tokens <= sum(enqueued.values()) + 1e-9
    # retiring every request's remainder conserves exactly to zero
    for rid in list(st.pending_by_req):
        r.on_prefill_progress(name, st.pending_by_req[rid], rid=rid)
    assert st.pending_tokens == pytest.approx(0.0)
    assert st.pending_by_req == {}

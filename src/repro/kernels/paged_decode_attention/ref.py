"""Pure-jnp oracle for the paged decode-attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref


def paged_decode_attention_ref(q, k_pool, v_pool, block_tbl, lengths,
                               *, window: Optional[int] = None) -> jax.Array:
    """Single-token GQA attention over a paged KV pool.

    q:              (b, nq, hd) — the new token's queries.
    k_pool, v_pool: (n_pages, page, nkv, hd) — shared physical pages.
    block_tbl:      (b, max_pages) int32 — physical page of each logical
                    page; unmapped entries point at the trash page 0.
    lengths:        (b,) int32 — valid KV tokens per slot INCLUDING the
                    current one (the query sits at position lengths-1).
                    Slots with length 0 produce unspecified output.
    Returns (b, nq, hd).

    Implementation: gather the slot's pages into a dense contiguous view
    and defer to the dense decode oracle with positions rebuilt from the
    page geometry (token t of a slot lives at logical position t).
    """
    b = q.shape[0]
    page, nkv, hd = k_pool.shape[1:]
    k = k_pool[block_tbl].reshape(b, -1, nkv, hd).astype(q.dtype)
    v = v_pool[block_tbl].reshape(b, -1, nkv, hd).astype(q.dtype)
    S = k.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)[None]
    kv_pos = jnp.where(pos < lengths[:, None], pos, -1)
    q_pos = jnp.maximum(lengths.astype(jnp.int32) - 1, 0)
    return decode_attention_ref(q, k, v, q_pos, kv_pos, window=window)

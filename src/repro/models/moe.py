"""Top-k MoE with sort-based capacity dispatch (dropped-token, GShard-style
capacity but without materializing the (T, E, C) one-hot).

Dispatch is per batch row ("group" = one sequence), so with batch sharded
over the data axis no cross-shard communication is needed until the expert
einsum itself. Three sharding modes (see partitioning.tp_rules):

* MoE-TP (baseline, paper-faithful analogue): every expert's d_ff sharded
  over 'model'; experts replicated. No all-to-all.
* Expert-sharded SPMD ('act_expert' mapped): lets XLA propagate — measured
  to be pathological (it replicates the dispatch buffers; EXPERIMENTS.md
  §Perf pair 2, iteration 2).
* Explicit shard_map EP (``rules.mesh`` set + expert axis mapped): the
  expert buffers cross the mesh with a REAL all-to-all at the shard_map
  boundary, each model-rank computes only its own experts, and ZeRO-
  sharded expert weights are gathered over 'data' inside the kernel.
"""
from __future__ import annotations

from functools import partial
from math import ceil
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.models.partitioning import current_rules, shard


def capacity(cfg: ModelConfig, seq: int) -> int:
    moe = cfg.moe
    c = ceil(seq * moe.top_k / moe.n_experts * moe.capacity_factor)
    return max(1, min(c, seq * moe.top_k))


def moe_block(p, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (x + moe_out, aux_load_balance_loss)."""
    moe = cfg.moe
    B, S, d = x.shape
    E, k = moe.n_experts, moe.top_k
    C = capacity(cfg, S)

    h = rms_norm(x, p["norm"], cfg.norm_eps)
    logits = (h.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (B,S,E)
    gates, eidx = jax.lax.top_k(probs, k)                      # (B,S,k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (Switch-style) ----
    me = probs.mean(axis=(0, 1))                               # (E,)
    one_hot_top1 = jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # ---- sort-based position-in-expert ----
    T = S * k
    fe = eidx.reshape(B, T)                                    # expert of each selection
    sort_idx = jnp.argsort(fe, axis=1)                         # (B,T) stable
    sorted_e = jnp.take_along_axis(fe, sort_idx, axis=1)
    counts = jnp.sum(jax.nn.one_hot(fe, E, dtype=jnp.int32), axis=1)  # (B,E)
    starts = jnp.concatenate(
        [jnp.zeros((B, 1), jnp.int32), jnp.cumsum(counts, axis=1)[:, :-1]],
        axis=1)                                                # (B,E)
    pos_sorted = (jnp.arange(T)[None, :]
                  - jnp.take_along_axis(starts, sorted_e, axis=1))  # rank in expert
    keep = pos_sorted < C
    slot_sorted = jnp.where(keep, sorted_e * C + pos_sorted, E * C)  # E*C = trash

    # scatter tokens into (B, E*C+1, d) expert buffers
    tok_sorted = sort_idx // k                                 # original token index
    hk = jnp.take_along_axis(
        h, tok_sorted[..., None], axis=1)                      # (B,T,d)
    buf = jnp.zeros((B, E * C + 1, d), h.dtype)
    buf = jax.vmap(lambda bb, ss, hh: bb.at[ss].set(hh))(buf, slot_sorted, hk)
    ebuf = buf[:, : E * C].reshape(B, E, C, d)

    # ---- expert computation (gated MLP) ----
    rules = current_rules()
    if rules is not None and getattr(rules, "mesh", None) is not None \
            and rules.size("expert") > 1 and E % rules.size("expert") == 0:
        out = _expert_ffn_shard_map(p, ebuf, rules)
    else:
        ebuf = shard(ebuf, "batch", "act_expert", None, None)
        up = jnp.einsum("becd,edf->becf", ebuf, p["wi"])
        gate = jnp.einsum("becd,edf->becf", ebuf, p["wg"])
        act = jax.nn.silu(gate) * up
        act = shard(act, "batch", "act_expert", None, "act_ff")
        out = jnp.einsum("becf,efd->becd", act, p["wo"])
        out = shard(out, "batch", "act_expert", None, None)

    # ---- combine: gather back, weight by gates, sum over k ----
    obuf = jnp.concatenate(
        [out.reshape(B, E * C, d), jnp.zeros((B, 1, d), out.dtype)], axis=1)
    got = jax.vmap(lambda ob, ss: ob[ss])(obuf, slot_sorted)   # (B,T,d)
    gat_sorted = jnp.take_along_axis(
        gates.reshape(B, T), sort_idx, axis=1)
    got = got * jnp.where(keep, gat_sorted, 0.0)[..., None].astype(got.dtype)
    # scatter-add back to token order: token t receives its k selections
    y = jnp.zeros((B, S, d), got.dtype)
    y = jax.vmap(lambda yy, tt, gg: yy.at[tt].add(gg))(y, tok_sorted, got)
    y = shard(y, "batch", None, "act_embed")
    return x + y, aux


# ---------------------------------------------------------------------------
# Explicit expert parallelism (shard_map)
# ---------------------------------------------------------------------------

def _expert_ffn_shard_map(p, ebuf, rules):
    """Expert FFN with REAL expert parallelism.

    At the shard_map boundary XLA emits an all-to-all resharding ebuf from
    batch-sharded to (batch x expert)-sharded; each model-rank runs ONLY
    its E/ep experts; weights arrive ZeRO-sharded along d over 'data' and
    are all-gathered inside the kernel (per-layer, per-rank slice only —
    not every expert everywhere, which is what sank the SPMD attempt).
    """
    mesh = rules.mesh
    expert_axis = rules.rules.get("expert")            # e.g. 'model'
    zero_axis = rules.rules.get("embed")               # 'data' under ZeRO-3
    batch_axes = rules.rules.get("batch")

    wi, wg, wo = p["wi"], p["wg"], p["wo"]
    E, dd, f = wi.shape
    gather_d = (zero_axis is not None and
                dd % rules.axis_sizes.get(zero_axis, 1) == 0)

    w_spec = P(expert_axis, zero_axis if gather_d else None, None)
    wo_spec = P(expert_axis, None, zero_axis if gather_d else None)
    buf_spec = P(batch_axes, expert_axis, None, None)

    def kernel(eb, wi_l, wg_l, wo_l):
        # eb: (B_loc, E_loc, C, d); w*_l: (E_loc, d/z, f) / (E_loc, f, d/z)
        if gather_d:
            wi_l = jax.lax.all_gather(wi_l, zero_axis, axis=1, tiled=True)
            wg_l = jax.lax.all_gather(wg_l, zero_axis, axis=1, tiled=True)
            wo_l = jax.lax.all_gather(wo_l, zero_axis, axis=2, tiled=True)
        up = jnp.einsum("becd,edf->becf", eb, wi_l)
        gate = jnp.einsum("becd,edf->becf", eb, wg_l)
        return jnp.einsum("becf,efd->becd", jax.nn.silu(gate) * up, wo_l)

    fn = jax.shard_map(kernel, mesh=mesh,
                       in_specs=(buf_spec, w_spec, w_spec, wo_spec),
                       out_specs=buf_spec)
    return fn(ebuf, wi, wg, wo)

import jax
import pytest

# Tests run on the single CPU device (the dry-run sets its own
# XLA_FLAGS in-process; see src/repro/launch/dryrun.py).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)

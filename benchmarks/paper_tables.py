"""One benchmark per paper table/figure. Each returns a list of CSV rows
(first row = header). ``benchmarks.run`` prints all of them.

Absolute numbers differ from the paper (TPU v5e constants vs Ascend 910B;
DESIGN.md §2) — each benchmark states the paper's claim so the qualitative
reproduction is auditable side by side.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

from repro.configs import get_config
from repro.core.colocation import STAGE_MIX, interference_heatmap
from repro.core.costmodel import RDMA, V5E, CostModel
from repro.core.kv_transfer import plan as kv_plan
from repro.core.simulator import SHAREGPT_4O, VISUALWEB, simulate
from repro.models.frontend import PAPER_RESOLUTION_TOKENS

MODEL = "openpangu-7b-vl"
N_REQ = 256
SLO = (2000.0, 50.0)
SLO_ENC = (2000.0, 80.0)      # paper: Encode-disaggregation SLO


def table2_transmission_ablation() -> List[str]:
    """Paper Table 2: E-P async prefetch / P-D grouped KV ablation.

    Claim: prefetch cuts TTFT 16.6-21.7%, grouping 11.9-16%, both
    26.1-31.6%, with TPOT roughly unchanged."""
    model = get_config(MODEL)
    rows = ["table2,rate_req_s,variant,ttft_ms,dttft_pct,tpot_ms"]
    for rate in (2.0, 3.0):
        base = None
        for name, kv, ep in [
                ("baseline(layer_wise+sync)", "layer_wise", False),
                ("w_EP_async_prefetch", "layer_wise", True),
                ("w_PD_grouped", "grouped", False),
                ("EPD-Serve(both)", "grouped", True)]:
            m = simulate(model, "E-P-D", SHAREGPT_4O, rate=rate,
                         n_requests=N_REQ, seed=3, kv_scheme=kv, ep_async=ep)
            if base is None:
                base = m.mean_ttft_ms
            rows.append(
                f"table2,{rate},{name},{m.mean_ttft_ms:.1f},"
                f"{(m.mean_ttft_ms / base - 1) * 100:+.1f},"
                f"{m.mean_tpot_ms:.2f}")
    return rows


def table3_ep_prefetch_overlap() -> List[str]:
    """Paper Table 3: feature transfer vs scheduling latency by image
    resolution; overlap ~100% below 4K, 99.78% at 4K."""
    cm = CostModel(get_config(MODEL))
    rows = ["table3,resolution,tokens,transfer_ms,scheduling_ms,overlap_pct"]
    for res, n in PAPER_RESOLUTION_TOKENS.items():
        nb = cm.feature_bytes(n)
        tx = cm.feature_transfer_time(nb) * 1e3
        sc = cm.dispatch_latency(nb) * 1e3
        ov = min(tx, sc) / tx * 100
        rows.append(f"table3,{res[0]}x{res[1]},{n},{tx:.2f},{sc:.2f},{ov:.2f}")
    return rows


def table4_kv_grouping() -> List[str]:
    """Paper Table 4 / Fig 7: layer-wise vs hierarchically-grouped KV
    transmission at seq 1024/2048, concurrency 16.

    Claim: overlap 15-25% -> ~99%; bandwidth +58% (1024) / +10% (2048)."""
    model = get_config(MODEL)
    cm = CostModel(model, RDMA)
    rows = ["table4,seq_len,scheme,kv_ms,exposed_ms,prefill_ms,"
            "overlap_pct,bandwidth_GBps"]
    conc = 16
    for seq in (1024, 2048):
        prefill = cm.prefill_time(seq) * conc      # batched prefill pass
        payload = cm.kv_bytes(seq) * conc
        for scheme in ("layer_wise", "grouped"):
            p = kv_plan(scheme, n_layers=model.n_layers,
                        bytes_per_layer=payload / model.n_layers,
                        per_layer_compute=prefill / model.n_layers,
                        handshake=RDMA.handshake, link_bw=RDMA.link_bw)
            rows.append(
                f"table4,{seq},{scheme},{p.kv_latency * 1e3:.1f},"
                f"{p.exposed_latency * 1e3:.2f},{p.prefill_end * 1e3:.0f},"
                f"{p.overlap_ratio * 100:.2f},"
                f"{p.effective_bandwidth / 1e9:.2f}")
    return rows


def figs8_11_encode_disaggregation() -> List[str]:
    """Paper Figs 8-11: TP1 / TP2 / E-PD / (E-PD) across request rates.

    Claim: (E-PD) beats TP1 on throughput and SLO; dedicated-chip E-PD
    wastes the Encode chip; TP2 saturates first (sync overhead).
    Rates are per-NPU (figure x-axis)."""
    model = get_config(MODEL)
    rows = ["figs8_11,dataset,rate_per_npu,deployment,n_chips,slo_pct,"
            "tput_tok_s_per_chip,ttft_ms,tpot_ms"]
    for ds_name, ds in (("sharegpt4o", SHAREGPT_4O), ("visualweb", VISUALWEB)):
        for rate in (2.0, 4.0, 6.0, 8.0):
            for dep in ("TP1", "TP2", "E-PD", "(E-PD)"):
                m = simulate(model, dep, ds, rate=rate, n_requests=N_REQ,
                             seed=5, per_chip_rate=True)
                rows.append(
                    f"figs8_11,{ds_name},{rate},{dep},{m.n_chips},"
                    f"{m.slo_attainment(*SLO_ENC) * 100:.1f},"
                    f"{m.throughput_tok_s / m.n_chips:.1f},"
                    f"{m.mean_ttft_ms:.1f},{m.mean_tpot_ms:.2f}")
    return rows


def figs12_15_decode_disaggregation() -> List[str]:
    """Paper Figs 12-15: EP-D / (E-P)-D / (E-D)-P vs TP1/TP2.

    Claim: decode disaggregation cuts TPOT 80-93%; (E-D)-P best TTFT;
    (E-P)-D best balanced/SLO."""
    model = get_config(MODEL)
    rows = ["figs12_15,rate_per_npu,deployment,n_chips,slo_pct,"
            "tput_tok_s_per_chip,ttft_ms,tpot_ms"]
    for rate in (2.0, 3.0, 4.0):
        for dep in ("TP1", "TP2", "EP-D", "(E-P)-D", "(E-D)-P"):
            m = simulate(model, dep, SHAREGPT_4O, rate=rate, n_requests=N_REQ,
                         seed=5, per_chip_rate=True)
            rows.append(
                f"figs12_15,{rate},{dep},{m.n_chips},"
                f"{m.slo_attainment(*SLO) * 100:.1f},"
                f"{m.throughput_tok_s / m.n_chips:.1f},"
                f"{m.mean_ttft_ms:.1f},{m.mean_tpot_ms:.2f}")
    return rows


def table5_full_epd() -> List[str]:
    """Paper Table 5: all deployments at one high total load.

    Claim: only decode-disaggregated deployments meet TPOT<=50ms; E-P-D
    attains the highest SLO and per-NPU effective throughput (7.95x EP-D)."""
    model = get_config(MODEL)
    rows = ["table5,deployment,n_chips,ttft_ms,tpot_ms,slo_pct,"
            "eff_tput_tok_s_per_chip"]
    for dep, reps in [("TP1", 2), ("(E-PD)", 2), ("EP-D", 1), ("(E-P)-D", 1),
                      ("(E-D)-P", 1), ("E-P-D", 1)]:
        m = simulate(model, dep, SHAREGPT_4O, rate=8.0, n_requests=2 * N_REQ,
                     seed=9, replicas=reps)
        name = f"{dep}x{reps}" if reps > 1 else dep
        rows.append(
            f"table5,{name},{m.n_chips},{m.mean_ttft_ms:.1f},"
            f"{m.mean_tpot_ms:.2f},{m.slo_attainment(*SLO) * 100:.2f},"
            f"{m.effective_throughput(*SLO):.2f}")
    return rows


def fig6_colocation_heatmap() -> List[str]:
    """Paper Fig 6: stage/operator co-location interference. Claim:
    similar resource profiles interfere strongly, complementary ones
    weakly (E|D < E|P < P|P)."""
    rows = ["fig6,stage,concurrent,slowdown"]
    for (a, b), v in sorted(interference_heatmap().items()):
        rows.append(f"fig6,{a},{b},{v:.3f}")
    rows.append("fig6_mix,stage," + ",".join(
        f"{op}" for op in ("matmul", "vector", "dma", "collective")))
    for st, mix in STAGE_MIX.items():
        rows.append("fig6_mix," + st + "," + ",".join(
            f"{mix[o]:.2f}" for o in ("matmul", "vector", "dma",
                                      "collective")))
    return rows


def fig17_slo_regimes() -> List[str]:
    """Paper Fig 17 / §4.7: per-regime winners. Claim: (E-P)-D for
    balanced latency, (E-D)-P for TTFT, (E-PD) for raw throughput."""
    model = get_config(MODEL)
    deps = ("TP1", "E-PD", "(E-PD)", "EP-D", "(E-P)-D", "(E-D)-P", "E-P-D")
    rows = ["fig17,rate_per_npu,best_ttft,best_tpot,best_tput_per_chip"]
    for rate in (4.0, 6.0, 8.0):
        res = {d: simulate(model, d, SHAREGPT_4O, rate=rate,
                           n_requests=N_REQ, seed=13, per_chip_rate=True)
               for d in deps}
        best_ttft = min(res, key=lambda d: res[d].mean_ttft_ms)
        best_tpot = min(res, key=lambda d: res[d].mean_tpot_ms)
        best_tput = max(res,
                        key=lambda d: res[d].throughput_tok_s / res[d].n_chips)
        rows.append(f"fig17,{rate},{best_ttft},{best_tpot},{best_tput}")
    return rows


ALL_BENCHMARKS = [
    table2_transmission_ablation,
    table3_ep_prefetch_overlap,
    table4_kv_grouping,
    figs8_11_encode_disaggregation,
    figs12_15_decode_disaggregation,
    table5_full_epd,
    fig6_colocation_heatmap,
    fig17_slo_regimes,
]

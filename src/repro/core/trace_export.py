"""Chrome/Perfetto trace-event JSON export for telemetry spans.

Converts a :class:`~repro.core.telemetry.Tracer`'s recorded spans into
the Trace Event Format (the JSON schema consumed by ``chrome://tracing``
and https://ui.perfetto.dev): one "X" (complete) event per span with
``ts``/``dur`` in microseconds, plus "M" (metadata) events naming one
thread row per telemetry track. Every engine instance / transfer link
gets its own row, so chunked-prefill compute on the P track visibly
overlaps group transfers on the link track, and preemption gaps show as
holes in a D track.

``validate_trace`` is the schema check used by tests and the CI
observability-smoke job — it asserts the exported JSON is loadable by
the viewers (required keys, µs units, non-negative durations, metadata
rows for every referenced track) without needing Chrome in the loop.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from .telemetry import Span, Tracer

# All spans share one synthetic process; each telemetry track becomes a
# thread row. tids are assigned in first-appearance order so related
# tracks (engine, then its link) sort adjacently in the viewer.
_PID = 1


def _track_tids(spans: List[Span]) -> Dict[str, int]:
    tids: Dict[str, int] = {}
    for s in spans:
        if s.track not in tids:
            tids[s.track] = len(tids) + 1
    return tids


def to_trace_events(tracer: Tracer,
                    process_name: str = "epd-serve") -> List[Dict[str, Any]]:
    """Spans -> trace-event dicts (µs timestamps, one tid per track)."""
    spans = tracer.spans
    tids = _track_tids(spans)
    events: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
        "args": {"name": process_name},
    }]
    for track, tid in tids.items():
        events.append({
            "name": "thread_name", "ph": "M", "pid": _PID, "tid": tid,
            "args": {"name": track},
        })
        events.append({
            "name": "thread_sort_index", "ph": "M", "pid": _PID, "tid": tid,
            "args": {"sort_index": tid},
        })
    for s in spans:
        args: Dict[str, Any] = dict(s.attrs)
        if s.request_id is not None:
            args["request_id"] = s.request_id
        if s.parent is not None:
            args["parent"] = s.parent
        events.append({
            "name": s.name, "ph": "X", "pid": _PID, "tid": tids[s.track],
            "ts": s.start * 1e6, "dur": s.duration * 1e6,
            "cat": s.name.split(".", 1)[0],
            "args": args,
        })
    return events


def write_trace(tracer: Tracer, path: str,
                process_name: str = "epd-serve") -> int:
    """Write ``{"traceEvents": [...]}`` JSON to ``path``; returns the
    number of span ("X") events written."""
    events = to_trace_events(tracer, process_name)
    with open(path, "w") as f:
        json.dump({"traceEvents": events,
                   "displayTimeUnit": "ms"}, f)
    return sum(1 for e in events if e["ph"] == "X")


def validate_trace(doc: Any,
                   require_tracks: Optional[List[str]] = None) -> Dict[str, int]:
    """Schema-validate a trace-event document (parsed JSON).

    Asserts the shape ``chrome://tracing`` / Perfetto require: a
    ``traceEvents`` list whose "X" events carry numeric ``ts``/``dur``
    (µs, dur >= 0) plus ``pid``/``tid``/``name``, and whose every
    referenced tid has a ``thread_name`` metadata row. When
    ``require_tracks`` is given, each named track must exist and hold
    at least one span. Returns ``{track_name: span_count}``.
    """
    assert isinstance(doc, dict), "trace document must be a JSON object"
    events = doc.get("traceEvents")
    assert isinstance(events, list), "traceEvents must be a list"
    names_by_tid: Dict[int, str] = {}
    for e in events:
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            names_by_tid[e["tid"]] = e["args"]["name"]
    counts: Dict[str, int] = {name: 0 for name in names_by_tid.values()}
    for e in events:
        ph = e.get("ph")
        assert ph in ("X", "M"), f"unexpected event phase {ph!r}"
        if ph != "X":
            continue
        for key in ("name", "pid", "tid", "ts", "dur"):
            assert key in e, f"span event missing {key!r}: {e}"
        assert isinstance(e["ts"], (int, float)), "ts must be numeric (µs)"
        assert isinstance(e["dur"], (int, float)), "dur must be numeric (µs)"
        assert e["dur"] >= 0, f"negative duration in {e['name']!r}"
        track = names_by_tid.get(e["tid"])
        assert track is not None, (
            f"span {e['name']!r} references tid {e['tid']} with no "
            f"thread_name metadata row")
        counts[track] += 1
    for want in require_tracks or []:
        assert want in counts, (
            f"required track {want!r} missing; have {sorted(counts)}")
        assert counts[want] > 0, f"required track {want!r} has no spans"
    return counts


def overlap(doc: Any, track_a: str, span_a: str,
            track_b: str, span_b: str) -> float:
    """Total seconds during which some ``span_a`` on ``track_a``
    overlaps some ``span_b`` on ``track_b`` — the measurement behind
    "chunk k's transfer runs under chunk k+1's compute". Span names
    match by prefix so ``"prefill.chunk"`` covers every chunk index."""
    events = doc["traceEvents"]
    names_by_tid = {e["tid"]: e["args"]["name"] for e in events
                    if e.get("ph") == "M" and e.get("name") == "thread_name"}

    def _spans(track: str, name: str):
        return sorted((e["ts"], e["ts"] + e["dur"]) for e in events
                      if e.get("ph") == "X"
                      and names_by_tid.get(e["tid"]) == track
                      and e["name"].startswith(name))

    total = 0.0
    for a0, a1 in _spans(track_a, span_a):
        for b0, b1 in _spans(track_b, span_b):
            if b0 >= a1:
                break
            total += max(0.0, min(a1, b1) - max(a0, b0))
    return total / 1e6

"""Analytic FLOP / HBM-byte estimates per (arch x shape).

XLA's ``cost_analysis()`` on scanned (while-loop) modules counts each loop
body ONCE — a 40-layer scan x 16-microbatch accumulation undercounts by
~640x. Collectives we trip-correct from the HLO (launch/hlo.py); for
FLOPs and HBM bytes an analytic model of our own forward/backward is both
more transparent and sharding-independent. Conventions:

* FLOPs: 2 per MAC; attention is causal (x0.5 of the full square), capped
  by the sliding window where present; MoE counts top-k x capacity-factor
  experts; backward = 2x forward; remat re-runs forward (total 4x fwd).
* HBM bytes (per device): parameters are streamed once per (micro)batch
  pass, KV/SSM caches read+written, activations ~12 residual-stream
  passes per layer, logits in f32. Attention score tiles are assumed
  VMEM-resident (the Pallas flash kernel's contract) — the jnp reference
  path would spill them, which is precisely the traffic the kernel
  removes.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import InputShape, ModelConfig


@dataclass
class Estimate:
    flops_global: float
    hbm_bytes_per_device: float


def _attn_ctx(cfg: ModelConfig, shape: InputShape) -> float:
    """Mean attended context per query token."""
    if shape.kind in ("train", "prefill"):
        full = shape.seq_len / 2.0                     # causal mean
        if cfg.sliding_window:
            return min(full, cfg.sliding_window)
        return full
    # decode: one token attends the whole cache (or window)
    kv = shape.seq_len
    if cfg.sliding_window:
        kv = min(kv, cfg.sliding_window)
    return kv


def forward_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Global forward FLOPs for one step of this shape."""
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind in ("train", "prefill") else 1)
    # matmul flops over active params (embeds excluded from matmul cost,
    # lm head included)
    n_embed = cfg.vocab * cfg.d_model
    head = n_embed if not cfg.tie_embeddings else cfg.vocab * cfg.d_model
    n_mat = cfg.active_param_count() - n_embed - head + head
    f = 2.0 * n_mat * tokens
    # attention quadratic term
    n_attn = len(cfg.attn_layers)
    if n_attn and cfg.n_heads:
        ctx = _attn_ctx(cfg, shape)
        f += 4.0 * n_attn * tokens * ctx * cfg.q_dim
        if cfg.encoder is not None:   # cross-attention over encoder ctx
            f += 4.0 * cfg.n_layers * tokens * cfg.encoder.n_ctx * cfg.q_dim
            # encoder itself (only when frames are consumed)
            if shape.kind in ("train", "prefill"):
                enc_tokens = shape.global_batch * cfg.encoder.n_ctx
                enc_params = 4 * cfg.d_model ** 2 + 2 * cfg.d_model * cfg.d_ff
                f += 2.0 * cfg.encoder.n_layers * enc_params * enc_tokens
                f += 4.0 * cfg.encoder.n_layers * enc_tokens \
                    * cfg.encoder.n_ctx * cfg.q_dim
    # SSD scan: per token per ssm layer ~ (6 inner N) for state update/out
    # + chunk-quadratic intra-chunk term amortized ~ (2 L N + 2 L P) ~ small
    if cfg.ssm is not None and cfg.ssm_layers:
        inner = cfg.ssm.inner_dim(cfg.d_model)
        nh = cfg.ssm.n_heads(cfg.d_model)
        per_tok = 6.0 * nh * cfg.ssm.head_dim * cfg.ssm.state_dim
        if shape.kind in ("train", "prefill"):
            per_tok += 4.0 * cfg.ssm.chunk_size * (
                cfg.ssm.state_dim + cfg.ssm.head_dim)
        f += len(cfg.ssm_layers) * per_tok * tokens
    return f


def step_flops(cfg: ModelConfig, shape: InputShape) -> float:
    fwd = forward_flops(cfg, shape)
    if shape.kind == "train":
        return 4.0 * fwd            # fwd + bwd(2x) + remat re-fwd(1x)
    return fwd


def cache_bytes(cfg: ModelConfig, shape: InputShape, decode_clamp: bool,
                kv_elem_bytes: int = 2) -> float:
    """Global KV + SSM cache size for this shape."""
    b = shape.global_batch
    s = shape.seq_len
    total = 0.0
    n_attn = len(cfg.attn_layers)
    if n_attn:
        eff = s
        if decode_clamp and cfg.sliding_window and \
                all(sp.mixer != "attn" for sp in cfg.pattern):
            eff = min(s, cfg.sliding_window)
        total += n_attn * b * eff * 2 * cfg.kv_dim * kv_elem_bytes
    if cfg.ssm is not None and cfg.ssm_layers:
        nh = cfg.ssm.n_heads(cfg.d_model)
        total += len(cfg.ssm_layers) * b * (
            nh * cfg.ssm.head_dim * cfg.ssm.state_dim * 4       # f32 state
            + (cfg.ssm.conv_width - 1) * (
                cfg.ssm.inner_dim(cfg.d_model) + 2 * cfg.ssm.state_dim) * 2)
    if cfg.encoder is not None:
        total += cfg.n_layers * b * cfg.encoder.n_ctx * 2 * cfg.kv_dim * 2
    return total


def step_hbm_bytes(cfg: ModelConfig, shape: InputShape, n_chips: int,
                   num_microbatches: int = 1,
                   kv_elem_bytes: int = 2) -> float:
    """Per-device HBM traffic for one step."""
    p_bytes = cfg.param_count() * 2                 # bf16, sharded
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind in ("train", "prefill") else 1)
    act = 12.0 * cfg.n_layers * tokens * cfg.d_model * 2
    if shape.kind == "train":
        # params re-streamed per microbatch x (fwd + remat-fwd + bwd)
        traffic = p_bytes * num_microbatches * 3
        traffic += act * 3
        # grads f32 rw + adam state rw
        traffic += cfg.param_count() * 4 * 4
        logits = tokens * cfg.vocab * 4 / max(num_microbatches, 1) \
            * num_microbatches  # each micro writes+reads its logits once
        traffic += logits * 2
    elif shape.kind == "prefill":
        traffic = p_bytes + act
        traffic += cache_bytes(cfg, shape, False, kv_elem_bytes)  # cache write
        traffic += shape.global_batch * cfg.vocab * 4
    else:
        traffic = p_bytes + act
        traffic += cache_bytes(cfg, shape, True, kv_elem_bytes)   # cache read
        traffic += shape.global_batch * cfg.vocab * 4
    return traffic / n_chips

"""REAL-compute EPD mini-cluster.

Wires actual JAX ``Engine`` instances (repro.serving.engine) through the
same EPD-Serve machinery the simulator uses — MM Store, modality-aware
router, E->P prefetch bookkeeping, P->D grouped KV transfer planning —
so the disaggregation logic is exercised end-to-end with real tensors on
CPU-scale configs. This is deliverable (b)'s serving driver and the
integration-test backbone.

Stage mapping:
* Encode instance  — runs the (stubbed) frontend + owns the MM Store put.
* Prefill instance — fetches features by hash from the MM Store
  (recomputing on a miss — fault-tolerance path), runs real prefill,
  exports the prefilled cache pytree (the "KV payload").
* Decode instance  — imports caches via the grouped transfer planner
  (payload bytes measured from the actual arrays) and continuous-batches
  decode steps.

Co-located stages share one Engine's params but keep separate logical
queues, mirroring the paper's logical-isolation/physical-co-location.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.batching import (IterationScheduler, PrefillJob,
                                 StreamTimeline)
from repro.core.costmodel import CostModel, Hardware, V5E
from repro.core.deployment import Deployment, InstanceSpec
from repro.core.faults import (DEFAULT_RETRY, NO_RETRY, NoFreeSlot,
                               SITE_DECODE_CRASH,
                               SITE_STORE_FETCH, FaultInjector, FaultPlan,
                               InstanceDown, RetryPolicy, TransferError)
from repro.core.scheduler import Router
from repro.core.ep_prefetch import EPPrefetcher
from repro.core.events import EventLoop
from repro.core.kv_transfer import (TransferPlan, emit_spans,
                                    plan as kv_plan,
                                    plan_chunked as kv_plan_chunked)
from repro.core.mm_store import MMStore
from repro.core.telemetry import (NULL_TRACER, LatencyAccountant,
                                  MetricsRegistry, Tracer)
from repro.models import frontend as FE
from repro.serving.encode_engine import EncodeEngine
from repro.serving.engine import Engine
from repro.serving.kv_pool import PoolExhausted
from repro.serving.request import Request


def cache_nbytes(caches) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))


@dataclass
class ClusterReport:
    completed: List[Request] = field(default_factory=list)
    kv_plans: List[TransferPlan] = field(default_factory=list)
    recomputes: int = 0
    # page-level preemption on the Decode engine
    preemptions: int = 0
    swapped_pages: int = 0           # host-link pages moved (out + in)
    admission_denials: int = 0       # inserts denied by the decode pool
    # fault recovery (chaos layer): per-arm counters and every request
    # the cluster gave up on — losses are surfaced, never silent. The
    # retry counters/time live in the cluster-wide metrics registry
    # (labeled by site); the historical names read through below.
    instance_crashes: int = 0
    reroutes: int = 0
    swap_losses: int = 0
    lost: List[Request] = field(default_factory=list)
    metrics: MetricsRegistry = field(default_factory=MetricsRegistry)

    # -- registry read-through (historical counter names) --------------------
    @property
    def retry_time_total(self) -> float:
        """Modeled retry/backoff seconds charged into latency accounting,
        across every recovery site (store fetch + transfer)."""
        return self.metrics.total("retry_time_seconds_total")

    @property
    def store_retries(self) -> int:
        return int(self.metrics.value("recovery_retries_total",
                                      site=SITE_STORE_FETCH))

    @property
    def transfer_retries(self) -> int:
        return int(self.metrics.value("recovery_retries_total",
                                      site="transfer"))

    @property
    def transfer_replans(self) -> int:
        return int(self.metrics.value("transfer_replans_total"))

    @property
    def encode_skips(self) -> int:
        """Encode forwards skipped outright because the (mm-hash,
        token-run) prefix key already covered the whole image run."""
        return int(self.metrics.value("encode_skips_total"))

    @property
    def mean_kv_overlap(self) -> float:
        if not self.kv_plans:
            return 1.0
        return sum(p.overlap_ratio for p in self.kv_plans) / len(self.kv_plans)


class EPDCluster:
    """E / P / D as separate engines over shared params (disaggregated)."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 128, kv_scheme: str = "grouped",
                 hw: Hardware = V5E, paged: bool = False,
                 page_size: int = 16, prefix_cache: bool = False,
                 n_prefill_pool_pages: Optional[int] = None,
                 chunked_prefill: bool = False, prefill_chunk: int = 32,
                 preemption: bool = False,
                 n_decode_pool_pages: Optional[int] = None,
                 n_decode: int = 1,
                 n_encode: int = 1, ep_overlap: str = "async",
                 faults: Optional[FaultPlan] = None,
                 retry: Optional[RetryPolicy] = None,
                 recovery: bool = True,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        # telemetry plane: one metrics registry + one span tracer + one
        # latency accountant for the whole cluster. The accountant's
        # clock is wall time (sync at every state transition) PLUS
        # modeled charges (transfer exposure, retry backoff) — the same
        # virtual timebase retry_time accounting already used; the
        # tracer is re-clocked onto it so wall spans and modeled
        # transfer spans share one timeline.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.acc = LatencyAccountant(wall=time.perf_counter)
        if tracer is not None:
            tracer.set_clock(self.acc.clock)
        self._queue_since: Dict[int, float] = {}
        # one fault plane across every failure domain: store fetches,
        # transfer groups, decode instances, and the swap tier all draw
        # from the same seeded injector. faults=None keeps the zero-fault
        # fast paths byte-identical to the pre-chaos cluster.
        self.faults = faults
        self.injector = FaultInjector(faults, metrics=self.metrics)
        if retry is not None:
            self.retry = retry
        else:
            # with a fault plan the recovery arms get the standard
            # backoff policy; without one NO_RETRY preserves the legacy
            # single-attempt store semantics (§3.2 recompute) exactly
            self.retry = DEFAULT_RETRY if faults is not None else NO_RETRY
        self.recovery = recovery
        self.store = MMStore(injector=self.injector)
        self.cost = CostModel(cfg, hw,
                              page_tokens=page_size if paged else 0)
        # Encode stage: real EncodeEngine instances (round-robin) feeding
        # the MM Store, with the E->P hand-off modeled per ep_overlap:
        #   async  — hash-only announce; the feature transfer hides under
        #            dispatch + the pre-image text prefill (RServe-style
        #            barrier only at image-token positions);
        #   sync   — the feature pushes E->P serially before prefill;
        #   inline — encode folds into the prefill instance (no transfer).
        # Arms differ ONLY in modeled accounting charges: the same
        # features flow through the same jitted forwards, so greedy
        # output is bit-identical across all three.
        if ep_overlap not in ("async", "sync", "inline"):
            raise ValueError(f"unknown ep_overlap mode {ep_overlap!r}")
        if n_encode < 1:
            raise ValueError("need n_encode >= 1")
        self.ep_overlap = ep_overlap
        self.encode_engines = (
            [EncodeEngine(cfg, params, store=self.store, name=f"E{i}",
                          tracer=self.tracer, metrics=self.metrics)
             for i in range(n_encode)]
            if cfg.frontend is not None else [])
        self._next_encode = 0
        self._ep_loop = EventLoop()
        self.prefetcher = EPPrefetcher(self._ep_loop, self.store, self.cost,
                                       async_mode=(ep_overlap == "async"))
        self._encode_skipped: set = set()
        self.kv_scheme = kv_scheme
        self.paged = paged
        self.chunked_prefill = chunked_prefill
        # Prefill engine: batch 1 (prefill is per-request); carries the
        # radix prefix cache when enabled (hits skip prefill compute for
        # the shared pages and the transfer planner charges suffix-only)
        # and the chunked-prefill window (each chunk's pages stream to
        # Decode while the next chunk computes).
        self.prefill_engine = Engine(cfg, params, max_batch=1,
                                     max_len=max_len, paged=paged,
                                     page_size=page_size,
                                     prefix_cache=prefix_cache,
                                     n_pool_pages=n_prefill_pool_pages,
                                     chunked_prefill=chunked_prefill,
                                     prefill_chunk=prefill_chunk,
                                     name="P0", tracer=self.tracer,
                                     metrics=self.metrics,
                                     accountant=self.acc)
        # Decode instances: preemption=True turns decode-side pool
        # pressure into page-level swap-to-host + resume instead of a
        # pool error; n_decode_pool_pages sizes the pool below
        # worst-case for overload experiments; n_decode > 1 gives the
        # crash re-route arm a surviving instance to land on.
        if n_decode < 1:
            raise ValueError("need n_decode >= 1")
        self.decode_engines = [
            Engine(cfg, params, max_batch=max_batch, max_len=max_len,
                   paged=paged, page_size=page_size,
                   n_pool_pages=n_decode_pool_pages,
                   preemption=preemption, faults=self.injector,
                   name=f"D{i}", tracer=self.tracer,
                   metrics=self.metrics, accountant=self.acc)
            for i in range(n_decode)]
        self.dead: set = set()           # indices of crashed instances
        self.report = ClusterReport(metrics=self.metrics)
        self._pending: List[Request] = []
        # crash-harvested requests waiting for re-admission: (request,
        # the decode-input token the resumed slot must feed next)
        self._reroute_queue: List[Request] = []
        # modeled stream clock: enable_timeline() attaches a FUSED clock
        # to the serial driver (one device, stages serialize);
        # run_continuous builds its own per-stage StreamTimeline. Both
        # charge the same CostModel durations, so serial vs continuous
        # makespans compare apples-to-apples.
        self.timeline: Optional[StreamTimeline] = None
        self.continuous_timeline: Optional[StreamTimeline] = None
        self.continuous_scheduler: Optional[IterationScheduler] = None
        # ground-truth Router (continuous mode): built over the REAL
        # engine names and fed chunk-granular occupancy as chunks
        # actually execute, not callback estimates
        self.router: Optional[Router] = None

    # ---- decode-instance topology ----
    @property
    def decode_engine(self) -> Engine:
        """First live decode instance (single-instance compatibility)."""
        return self.decode_engines[self.live_decode_indices()[0]]

    def live_decode_indices(self) -> List[int]:
        out = [i for i in range(len(self.decode_engines))
               if i not in self.dead]
        if not out:
            raise InstanceDown("all-decode", 0)
        return out

    def _pick_decode(self) -> Optional[Engine]:
        """Least-loaded live instance with a free slot (ties -> lowest
        index, so placement is deterministic); None when every live
        instance is full."""
        best = None
        best_free = 0
        for i in self.live_decode_indices():
            free = len(self.decode_engines[i].free_slots())
            if free > best_free:
                best, best_free = self.decode_engines[i], free
        return best

    # ---- latency attribution / queue-span helpers ----
    def _park_queued(self, req: Request) -> None:
        """A request (re-)enters a wait queue: accountant state goes to
        ``queue`` and the wait start is remembered for the queue span."""
        self.acc.set_state(req.request_id, "queue")
        if self.tracer.enabled:
            self._queue_since.setdefault(req.request_id, self.acc.clock())

    def _unpark_queued(self, req: Request) -> None:
        """A queued request starts service: close its queue-wait span
        and move its accountant state to ``compute``."""
        self.acc.set_state(req.request_id, "compute")
        t0 = self._queue_since.pop(req.request_id, None)
        if t0 is not None and self.tracer.enabled:
            self.tracer.add("queue.wait", t0, self.acc.clock(),
                            track="router", request_id=req.request_id)

    def attribution(self) -> Dict[str, Any]:
        """Per-request TTFT/TPOT attribution report (see
        ``telemetry.LatencyAccountant.report``)."""
        self.acc.sync()
        return self.acc.report()

    # ---- modeled stream clock (serial baseline) ----
    def enable_timeline(self) -> StreamTimeline:
        """Attach a FUSED modeled clock to the serial driver: every
        stage charge serializes onto one stream, exactly how the serial
        chunk loop occupies a single python thread. The continuous
        benchmark divides its per-stage makespan by this baseline."""
        self.timeline = StreamTimeline(fused=True)
        return self.timeline

    def _modeled_prefill_times(self, req: Request, caches) -> List[float]:
        """Per-chunk modeled prefill durations for one finished payload
        (one entry for a monolithic prefill) — the same CostModel calls
        the transfer planner and the continuous scheduler charge."""
        cached = getattr(caches, "cached_tokens", 0)
        chunks = getattr(caches, "chunks", None)
        if chunks:
            return self.cost.chunk_prefill_times(
                req.total_prompt_len, [t for t, _ in chunks],
                cached_prefix=cached)
        return [self.cost.prefill_time(req.total_prompt_len,
                                       cached_prefix=cached)]

    # ---- Encode stage ----
    def _pick_encode(self) -> EncodeEngine:
        eng = self.encode_engines[self._next_encode
                                  % len(self.encode_engines)]
        self._next_encode += 1
        return eng

    def _can_skip_encode(self, req: Request, key: str) -> bool:
        """True when the prefill engine's radix tree already holds KV
        for the WHOLE image run under the (mm-hash, token-run) prefix
        key — then neither the encode forward nor the feature fetch is
        needed: the image's contribution to this prompt is entirely KV
        reuse (MM Store dedup composed with the prefix cache)."""
        pc = self.prefill_engine.prefix_cache
        if pc is None or self.cfg.encoder is not None or not req.mm_tokens:
            return False
        p = list(req.prompt_tokens)
        key_tokens = (p[:req.mm_pos] + FE.mm_key_run(key, req.mm_tokens)
                      + p[req.mm_pos:])
        run_end = req.mm_pos + req.mm_tokens
        if run_end > len(key_tokens) - 1:
            # the match is capped at n-1 (one token must be computed for
            # logits): a run reaching the last token can't be covered
            return False
        return pc.match_len(key_tokens, cap=len(key_tokens) - 1) >= run_end

    def encode(self, req: Request) -> Optional[str]:
        if not req.is_multimodal or not self.encode_engines:
            return None
        eng = self._pick_encode()
        key = FE.content_hash(req.mm_payload)
        if self._can_skip_encode(req, key):
            self.metrics.counter("encode_skips_total").inc()
            self._encode_skipped.add(req.request_id)
            if self.tracer.enabled:
                t = self.acc.clock()
                self.tracer.add("encode.skip", t, t, track=eng.name,
                                request_id=req.request_id)
            return key
        with self.tracer.span("encode", track=eng.name,
                              request_id=req.request_id):
            _, ran = eng.dispatch(req)
        if self.timeline is not None and ran:
            self.timeline.charge_encode(
                self.cost.encode_time(req.mm_tokens))
        return key

    # ---- E->P hand-off accounting (overlap arms) ----
    def _charge_ep_overlap(self, req: Request, key: str) -> None:
        """Charge the MODELED E->P hand-off latency for one feature per
        the overlap arm (the real arrays move in-process, like the P->D
        transfer). inline: zero — there is no E->P link. sync: dispatch
        plus the full feature push, serialized before prefill. async:
        hash-only announce; the transfer hides under dispatch + the
        pre-image TEXT prefill (chunks before ``mm_pos`` proceed while
        the feature is in flight), so only the exposed remainder — the
        RServe-style feature-arrival barrier at the first image-token
        position — delays the request."""
        if self.ep_overlap == "inline":
            return
        nbytes = self.cost.feature_bytes(req.mm_tokens)
        disp = self.cost.dispatch_latency(nbytes)
        xfer = self.cost.feature_transfer_time(nbytes)
        pre = 0.0
        if req.mm_pos > 0:
            pre = self.cost.chunk_prefill_times(
                req.total_prompt_len,
                [req.mm_pos, req.total_prompt_len - req.mm_pos])[0]
        if self.ep_overlap == "async":
            hint = disp + pre
            extra = disp + max(0.0, xfer - disp - pre)
        else:
            hint = 0.0
            extra = disp + xfer
        # the prefetcher records the announce->ready bookkeeping (its
        # overlap_ratio is the paper's Table 3 metric); the loop fires
        # the ready callback synchronously — features are already local
        self.prefetcher.notify(req.request_id, key, req.mm_tokens,
                               on_ready=lambda _rc: None,
                               scheduling_latency_hint=hint)
        self._ep_loop.run()
        self.acc.sync()
        t0 = self.acc.now
        self.acc.advance(extra, req.request_id, "transfer")
        if self.timeline is not None and extra > 0:
            self.timeline.charge_encode(extra)
        if self.tracer.enabled and extra > 0:
            self.tracer.add("ep.prefetch", t0, self.acc.now, track="store",
                            request_id=req.request_id,
                            mode=self.ep_overlap, nbytes=nbytes)

    # ---- Prefill stage (with FT retry + recompute on store miss) ----
    def prefill(self, req: Request, key: Optional[str]):
        if key is None:
            return self.prefill_engine.prefill_request(req)
        if req.request_id in self._encode_skipped:
            # full-run prefix hit: no features needed — prefill rides
            # the (mm-hash, token-run) radix key alone, and there is no
            # E->P transfer to charge
            self._encode_skipped.discard(req.request_id)
            return self.prefill_engine.prefill_request(req, mm_key=key)
        # layered store-fetch arm: retry with backoff per the policy
        # (attempt keys the injector's draw, so transient faults
        # heal), then fall back to the §3.2 local recompute. The
        # default NO_RETRY policy keeps the legacy single-attempt
        # behavior exactly.
        feats = self.store.get(key, record=False)
        attempt = 1
        while feats is None and attempt < self.retry.max_attempts:
            back = self.retry.backoff(attempt, key=key)
            self.metrics.counter("retry_time_seconds_total",
                                 site=SITE_STORE_FETCH).inc(back)
            self.metrics.counter("recovery_retries_total",
                                 site=SITE_STORE_FETCH).inc()
            # backoff is modeled time: charge it to the request's
            # retry component and render it on the store track
            self.acc.sync()
            t0 = self.acc.now
            self.acc.advance(back, req.request_id, "retry")
            if self.tracer.enabled:
                self.tracer.add("retry.store", t0, self.acc.now,
                                track="store",
                                request_id=req.request_id,
                                attempt=attempt)
            feats = self.store.get(key, record=False, attempt=attempt)
            attempt += 1
        if feats is None:
            # fault tolerance: recompute locally (paper §3.2) through
            # the SAME jitted frontend forward the Encode stage ran, so
            # the rebuilt features are bit-identical — and re-put under
            # the same hash (the dedup-put now adopts the fresh tuple)
            feats = self.encode_engines[0].compute_features(
                req.mm_payload, req.mm_tokens)
            self.store.put(key, feats, feats.nbytes)
            self.report.recomputes += 1
        else:
            self._charge_ep_overlap(req, key)
        feats = jnp.asarray(feats)[None]
        if self.cfg.encoder is not None:
            return self.prefill_engine.prefill_request(req, None, feats)
        return self.prefill_engine.prefill_request(req, mm_feats=feats,
                                                   mm_key=key)

    # ---- P->D transfer + Decode import ----
    def _build_kv_plan(self, req: Request, caches) -> TransferPlan:
        # paged payloads already carry their page-granular byte count;
        # dense payloads are measured from the actual arrays.
        nbytes = getattr(caches, "kv_nbytes", None)
        if nbytes is None:
            nbytes = cache_nbytes(caches)
        # prefix-cache hits shrink the prefill the transfer overlaps with:
        # only the computed suffix counts as per-layer compute.
        cached = getattr(caches, "cached_tokens", 0)
        chunks = getattr(caches, "chunks", None)
        if chunks:
            # streaming chunked prefill: segment k's pages (measured from
            # the actual payload) ship while segment k+1 computes; a
            # cached-prefix segment (0 computed tokens) is ready at t=0
            per_page = nbytes / max(len(caches.page_ids), 1)
            p = kv_plan_chunked(
                chunk_bytes=[n_pg * per_page for _, n_pg in chunks],
                chunk_compute=self.cost.chunk_prefill_times(
                    req.total_prompt_len, [toks for toks, _ in chunks],
                    cached_prefix=cached),
                handshake=self.cost.hw.handshake,
                link_bw=self.cost.hw.link_bw,
                page_bytes=self.cost.kv_page_bytes())
        else:
            p = kv_plan(self.kv_scheme,
                        n_layers=self.cfg.n_layers,
                        bytes_per_layer=nbytes / self.cfg.n_layers,
                        per_layer_compute=self.cost.per_layer_prefill_time(
                            req.total_prompt_len, cached_prefix=cached),
                        handshake=self.cost.hw.handshake,
                        link_bw=self.cost.hw.link_bw,
                        page_bytes=self.cost.kv_page_bytes_per_layer())
        return p

    def _count_transfer_recovery(self, rec) -> None:
        self.metrics.counter("recovery_retries_total",
                             site="transfer").inc(rec.retries)
        self.metrics.counter("transfer_replans_total").inc(
            rec.replanned_groups)
        self.metrics.counter("retry_time_seconds_total",
                             site="transfer").inc(rec.retry_time)

    def transfer_and_insert(self, req: Request, caches, first: int,
                            append_token: bool = True) -> Engine:
        p = self._build_kv_plan(req, caches)
        # deliver the plan through the fault plane: transfer groups
        # re-handshake/resend with backoff, exhausted groups replan
        # fresh; the retry time lands in retry_time_total (latency
        # accounting) and the *recovered* plan is what gets recorded.
        rec = None
        if self.faults is not None:
            p, rec = self.cost.recover_transfer(
                p, self.injector,
                self.retry if self.recovery else NO_RETRY,
                key=req.request_id, replan=self.recovery)
            self._count_transfer_recovery(rec)
        return self._insert_with_plan(req, caches, first, p, rec,
                                      append_token)

    def _insert_with_plan(self, req: Request, caches, first: int,
                          p: TransferPlan, rec, append_token: bool) -> Engine:
        engine = self._pick_decode() or self.decode_engine
        # The exposed transfer latency (and any retry backoff folded
        # into it by recovery) is modeled time — the real arrays move
        # in-process. Charge it on the accounting clock: retry time to
        # the retry component, the remaining exposure to transfer. The
        # modeled group schedule is anchored so its prefill_end lands
        # at the current accounting now (the real prefill just ended on
        # the wall clock).
        self.acc.sync()
        base = self.acc.now - p.prefill_end
        retry_t = rec.retry_time if rec is not None else 0.0
        exposed = max(0.0, p.exposed_latency - retry_t)
        self.acc.advance(retry_t, req.request_id, "retry")
        self.acc.advance(exposed, req.request_id, "transfer")
        emit_spans(self.tracer, p, base=base,
                   handshake=self.cost.hw.handshake,
                   compute_track=self.prefill_engine.name,
                   link_track=f"{self.prefill_engine.name}->{engine.name}",
                   request_id=req.request_id, recovery=rec)
        # insert may preempt a decode victim to make room; only a
        # successful admission records the transfer plan
        engine.insert(req, caches, first, append_token=append_token)
        self.acc.mark_first_token(req.request_id)
        self.acc.set_state(req.request_id, "compute")
        self.report.kv_plans.append(p)
        if self.timeline is not None:
            self.timeline.charge_decode(max(0.0, p.exposed_latency))
        return engine

    # ---- full pipeline ----
    def submit(self, req: Request) -> bool:
        """Run E->P and admit into Decode. Returns False when the decode
        pool denied admission (exhausted even after preemption would
        leave no active slot): the request re-queues at the front and
        its payload is released — it re-prefills on retry (the prefix
        cache, when enabled, makes that cheap). A request whose P->D
        transfer is unrecoverable (retry + replan exhausted, or any
        fault with recovery off) is killed and surfaced in
        ``report.lost`` — never silently dropped."""
        self.acc.open(req.request_id)
        if self._pick_decode() is None:
            self._park_queued(req)
            self._pending.append(req)
            return True
        self._unpark_queued(req)
        key = self.encode(req)
        first, caches = self.prefill(req, key)
        if self.timeline is not None:
            for dt in self._modeled_prefill_times(req, caches):
                self.timeline.charge_prefill(dt)
        try:
            self.transfer_and_insert(req, caches, first)
        except PoolExhausted:
            # insert raises before any mutation: no token was recorded
            if self.paged:
                self.prefill_engine.release_payload(caches)
            self.report.admission_denials += 1
            self._park_queued(req)
            self._pending.insert(0, req)
            return False
        except TransferError:
            if self.paged:
                self.prefill_engine.release_payload(caches)
            req.killed = True
            self.report.lost.append(req)
            self.acc.close(req.request_id)
        return True

    # ---- decode-instance crash + cross-instance re-route ----
    def _maybe_crash(self, step: int) -> None:
        """Consult the fault plane for instance crashes this step. The
        last live instance is never crashed (a zero-instance cluster has
        no recovery arm — that is a different failure class than the
        paper's elastic churn)."""
        for i in list(self.live_decode_indices()):
            if len(self.live_decode_indices()) <= 1:
                return
            if self.injector.should_fail(SITE_DECODE_CRASH, key=(i, step)):
                self._crash_instance(i)

    def _crash_instance(self, i: int) -> None:
        """Kill decode instance ``i`` mid-stream: its pool, KV, and swap
        store vanish with it. In-flight requests (active slots AND
        parked preemptees) are harvested for re-route when recovery is
        on, else killed into ``report.lost``. Either way every affected
        request is accounted for — never a silent drop."""
        if i in self.dead:
            raise InstanceDown(f"decode[{i}]", 0)
        eng = self.decode_engines[i]
        inflight = eng.mark_crashed()
        self.dead.add(i)
        if self.router is not None:
            self.router.on_instance_down(eng.name)
        self.report.instance_crashes += 1
        self.metrics.counter("instance_crashes_total",
                             engine=eng.name).inc()
        if self.tracer.enabled:
            t = self.acc.clock()
            self.tracer.add("crash", t, t, track=eng.name,
                            harvested=len(inflight))
        for req in inflight:
            if self.recovery:
                self._park_queued(req)
                self._reroute_queue.append(req)
            else:
                req.killed = True
                self.report.lost.append(req)
                self.acc.close(req.request_id)

    def _reroute_one(self, req: Request) -> bool:
        """Re-route one crash-harvested request to a surviving instance.

        At harvest time the request's KV covered
        ``prompt + output_tokens[:-1]`` and the next decode input was
        ``output_tokens[-1]`` — so a re-prefill of exactly that sequence
        (riding the prefix cache: only the uncached suffix recomputes)
        rebuilds bit-identical KV on the survivor, and ``insert`` with
        ``append_token=False`` resumes decode at the exact position.
        Returns False (request back at the queue head) when the
        survivor's pool denied admission — retried after decode drains."""
        seq = list(req.prompt_tokens) + list(req.output_tokens[:-1])
        shadow = Request(prompt_tokens=seq, max_new_tokens=1,
                         mm_payload=req.mm_payload,
                         mm_tokens=req.mm_tokens, mm_pos=req.mm_pos,
                         priority=req.priority)
        # the shadow prefill's charges (store retries, transfer
        # exposure) bill the original request's ledger entry
        self.acc.alias(shadow.request_id, req.request_id)
        self._unpark_queued(req)
        key = self.encode(shadow)
        first, caches = self.prefill(shadow, key)
        try:
            self.transfer_and_insert(req, caches,
                                     int(req.output_tokens[-1]),
                                     append_token=False)
        except PoolExhausted:
            if self.paged:
                self.prefill_engine.release_payload(caches)
            self.report.admission_denials += 1
            self._park_queued(req)
            self._reroute_queue.insert(0, req)
            return False
        except TransferError:
            if self.paged:
                self.prefill_engine.release_payload(caches)
            req.killed = True
            self.report.lost.append(req)
            self.acc.close(req.request_id)
            return True
        self.report.reroutes += 1
        return True

    def run_until_done(self, max_steps: int = 1000) -> List[Request]:
        steps = 0
        done: List[Request] = []

        def live():
            return [self.decode_engines[i]
                    for i in self.live_decode_indices()]

        while ((any(e.n_active or e.preempted for e in live())
                or self._pending or self._reroute_queue)
               and steps < max_steps):
            self._maybe_crash(steps)
            for eng in live():
                if eng.n_active or eng.preempted:
                    if self.timeline is not None and eng.n_active:
                        batch = eng.n_active
                        kv = sum(r.total_prompt_len + len(r.output_tokens)
                                 for r in eng.slots if r is not None) / batch
                        self.timeline.charge_decode(
                            self.cost.decode_step_time(batch, kv))
                    for r, _t, d in eng.decode_step():
                        if d:
                            done.append(r)
                            self.acc.close(r.request_id,
                                           n_output_tokens=len(
                                               r.output_tokens))
                # swap-loss casualties (no recompute arm available)
                while eng.lost:
                    lost = eng.lost.pop(0)
                    self.report.lost.append(lost)
                    self.acc.close(lost.request_id)
            # reconcile ledger states with where each request actually
            # is after the step (preemption may have parked a request:
            # parked time is queueing; resumed requests compute again),
            # then fold in the engines' measured swap durations — the
            # notes reclassify already-charged time, so they drain only
            # after the sync inside set_state has charged it.
            for eng in live():
                for pr in eng.preempted:
                    self.acc.set_state(pr.req.request_id, "queue")
                for r in eng.slots:
                    if r is not None:
                        self.acc.set_state(r.request_id, "compute")
            self.acc.sync()
            for eng in self.decode_engines:
                eng.drain_notes()
            self.prefill_engine.drain_notes()
            while self._reroute_queue and self._pick_decode() is not None:
                if not self._reroute_one(self._reroute_queue.pop(0)):
                    break                  # denied: wait for drain
            while self._pending and self._pick_decode() is not None:
                if not self.submit(self._pending.pop(0)):
                    break                  # denied: wait for decode to drain
            steps += 1
        self._finalize(done)
        return done

    def _finalize(self, done: List[Request]) -> None:
        """Close the run out: sync accounting, drain swap notes, fold
        engine counters into the report (shared by both drivers). Any
        engine-side casualty still sitting in ``eng.lost`` (filled
        outside a driver's own drain point) lands in ``report.lost``
        with its accountant record closed — losses are never silent."""
        self.acc.sync()
        for eng in self.decode_engines:
            self._harvest_engine_lost(eng, None)
            eng.drain_notes()
        self.prefill_engine.drain_notes()
        self.report.completed.extend(done)
        self.report.preemptions = sum(e.preempt_count
                                      for e in self.decode_engines)
        self.report.swapped_pages = sum(
            e.swap_out_pages_total + e.swap_in_pages_total
            for e in self.decode_engines)
        if self.paged:
            self.report.swap_losses = sum(e.pool.swap_lost_total
                                          for e in self.decode_engines)
        if self.prefetcher.records:
            self.metrics.gauge("ep_overlap_ratio").set(
                self.prefetcher.mean_overlap_ratio)

    # ---- continuous batching: the iteration-level cluster driver ----
    def _submit_continuous(self, req: Request, sched: IterationScheduler,
                           tl: StreamTimeline, router: Router) -> PrefillJob:
        """Fold the Encode dispatch into the serving loop and queue one
        prefill job. The async arm's E->P feature arrival becomes a REAL
        dependency edge: ``feature_ready_at`` gates only the chunk whose
        window overlaps the image run, so pre-image text chunks start
        while the feature is still in flight; the sync arm gates the
        whole job (``ready_at``); inline charges the encode forward on
        the prefill stream and has no link to wait on."""
        pe = self.prefill_engine
        # jobs the engine cannot serve through the resumable chunk state
        # machine — whisper-class encoder-decoder prefills (cross-attn
        # needs the full enc frames), or a non-chunked/non-paged prefill
        # engine — run MONOLITHIC: one unchunkable work item through
        # ``prefill_request``, still scheduled/admitted like any job.
        monolithic = (self.cfg.encoder is not None or not pe.paged
                      or pe._prefill_suffix is None)
        ready_at = 0.0
        feature_ready_at = 0.0
        meta: Dict[str, Any] = {}
        key = None
        if req.is_multimodal and self.encode_engines:
            eng = self._pick_encode()
            key = FE.content_hash(req.mm_payload)
            if self._can_skip_encode(req, key):
                # full-run radix hit: no forward, no features, no barrier
                self.metrics.counter("encode_skips_total").inc()
            else:
                with self.tracer.span("encode", track=eng.name,
                                      request_id=req.request_id):
                    _, ran = eng.dispatch(req)
                # the feature itself is fetched LAZILY at the barrier
                # chunk (``_fetch_features_continuous``) so a store
                # fault or mid-flight eviction surfaces inside the
                # iteration loop, where the §3.2 retry/recompute arms
                # are schedulable work — not at submit time.
                meta["needs_feats"] = True
                t_enc = self.cost.encode_time(req.mm_tokens) if ran else 0.0
                if self.ep_overlap == "inline":
                    if t_enc:
                        tl.charge_prefill(t_enc)
                else:
                    enc_done = (tl.charge_encode(t_enc) if t_enc
                                else tl.t_encode)
                    router.on_busy_until(eng.name, enc_done)
                    nbytes = self.cost.feature_bytes(req.mm_tokens)
                    arrival = (enc_done + self.cost.dispatch_latency(nbytes)
                               + self.cost.feature_transfer_time(nbytes))
                    if self.ep_overlap == "async" and not monolithic:
                        feature_ready_at = arrival
                    else:
                        # sync arm — or a monolithic prefill, whose one
                        # work item always overlaps the feature
                        ready_at = arrival
                    # announce->ready bookkeeping (Table-3 overlap ratio)
                    self.prefetcher.notify(req.request_id, key,
                                           req.mm_tokens,
                                           on_ready=lambda _rc: None)
                    self._ep_loop.run()
        meta["mm_key"] = key
        # whisper-class enc frames live on the ENCODER side: they do not
        # occupy decoder prefill positions
        n_mm = (req.mm_tokens
                if key is not None and self.cfg.encoder is None else 0)
        n_tokens = len(req.prompt_tokens) + n_mm
        job = PrefillJob(
            req=req, n_tokens=n_tokens,
            chunk=(n_tokens if monolithic
                   else pe.prefill_chunk if pe.chunked_prefill
                   else pe.max_len),
            ready_at=ready_at, feature_ready_at=feature_ready_at)
        if monolithic:
            meta["monolithic"] = True
        job.meta.update(meta)
        self._park_queued(req)
        router.on_enqueue(pe.name, job.n_tokens, rid=str(req.request_id))
        return sched.submit(job)

    def _restart_one_prefill(self, sched: IterationScheduler) -> bool:
        """Pool-deadlock recovery: every schedulable chunk stalled on
        the allocator and nothing else can free pages. Abort the
        YOUNGEST in-flight task (least work lost; the prefix cache, when
        on, keeps its finished chunks cheap to redo) and send its job
        back to the waiting queue. The Router ledger self-corrects: the
        restarted task's re-retirements are capped at what the request
        still owes."""
        for job in reversed(sched.live):
            if job.task is not None and not job.task.closed:
                job.task.abort()
                job.task = None
                job.meta.pop("chunk_times", None)
                sched.live.remove(job)
                sched.waiting.append(job)
                sched.note_stall(job, "restart")
                self._park_queued(job.req)
                return True
        return False

    def _fetch_features_continuous(self, job: PrefillJob,
                                   sched: IterationScheduler,
                                   tl: StreamTimeline) -> bool:
        """Lazy E->P feature fetch at the barrier chunk, with the store
        failure domain as SCHEDULER work instead of a synchronous retry
        loop: a faulted fetch (or a mid-flight eviction) pushes the
        job's barrier clock by the capped retry backoff — the plan
        composes around the parked job — and on policy exhaustion the
        §3.2 recompute runs as a schedulable encode work item whose
        modeled completion gates only this job's barrier chunk. Returns
        True once ``meta["mm_feats"]`` is populated; False means the
        job stalled this iteration (barrier pushed into the future)."""
        req = job.req
        key = job.meta["mm_key"]
        rid = req.request_id
        barrier = "ready_at" if job.meta.get("monolithic") \
            else "feature_ready_at"
        attempt = job.meta.get("store_attempts", 0)
        feats = self.store.get(key, record=False, attempt=attempt)
        if feats is not None:
            job.meta["mm_feats"] = jnp.asarray(feats)[None]
            return True
        attempt += 1
        job.meta["store_attempts"] = attempt
        base = max(tl.t_prefill, job.ready_at, job.feature_ready_at)
        nxt = self.retry.next_retry_at(base, attempt, key=key)
        if nxt is not None:
            back = nxt - base
            self.metrics.counter("retry_time_seconds_total",
                                 site=SITE_STORE_FETCH).inc(back)
            self.metrics.counter("recovery_retries_total",
                                 site=SITE_STORE_FETCH).inc()
            self.acc.sync()
            t0 = self.acc.now
            self.acc.advance(back, rid, "retry")
            if self.tracer.enabled:
                self.tracer.add("retry.store", t0, self.acc.now,
                                track="store", request_id=rid,
                                attempt=attempt)
            setattr(job, barrier, nxt)
            sched.note_stall(job, "store_retry")
            return False
        # policy exhausted (or single-attempt NO_RETRY): §3.2 local
        # recompute through the SAME jitted frontend forward — the
        # rebuilt features are bit-identical — charged on the ENCODE
        # stream as its own work item; its completion is this job's new
        # feature barrier and every other job keeps stepping meanwhile.
        feats = self.encode_engines[0].compute_features(
            req.mm_payload, req.mm_tokens)
        self.store.put(key, feats, feats.nbytes)
        self.report.recomputes += 1
        self.metrics.counter("continuous_recomputes_total").inc()
        t_enc = self.cost.encode_time(req.mm_tokens)
        done = tl.charge_encode(t_enc, not_before=tl.t_prefill)
        setattr(job, barrier, max(getattr(job, barrier), done))
        job.meta["mm_feats"] = jnp.asarray(feats)[None]
        sched.note_stall(job, "store_recompute")
        # stall until the modeled clock reaches the recompute completion
        return False

    def _advance_monolithic(self, job: PrefillJob,
                            sched: IterationScheduler, tl: StreamTimeline,
                            router: Router) -> bool:
        """Run an UNCHUNKABLE job as one scheduled work item: the whole
        prefill through ``prefill_request`` (whisper-class cross-attn
        decoders, or engines without the paged suffix step). The job
        admits/parks/retries exactly like a chunked one — only the
        prefill itself is indivisible."""
        pe = self.prefill_engine
        req = job.req
        rid = str(req.request_id)
        if job.meta.get("needs_feats") and job.meta.get("mm_feats") is None:
            if not self._fetch_features_continuous(job, sched, tl):
                return False
        feats = job.meta.get("mm_feats")
        self._unpark_queued(req)
        try:
            with self.tracer.span("prefill.monolithic", track=pe.name,
                                  request_id=req.request_id,
                                  tokens=job.n_tokens):
                if self.cfg.encoder is not None and feats is not None:
                    first, payload = pe.prefill_request(req, None, feats)
                elif feats is not None:
                    first, payload = pe.prefill_request(
                        req, mm_feats=feats, mm_key=job.meta.get("mm_key"))
                elif job.meta.get("mm_key") is not None:
                    first, payload = pe.prefill_request(
                        req, mm_key=job.meta["mm_key"])
                else:
                    first, payload = pe.prefill_request(req)
        except PoolExhausted:
            # the allocator raises before any mutation: retry after
            # decode drain / admission frees prefill pool pages
            sched.note_stall(job, "pool")
            self._park_queued(req)
            return False
        router.on_start(pe.name, 0, rid=rid)
        cached = getattr(payload, "cached_tokens", 0)
        dur = self.cost.prefill_time(max(job.n_tokens, 1),
                                     cached_prefix=cached)
        nb = max(job.ready_at, job.feature_ready_at)
        t_done = tl.charge_prefill(dur, not_before=nb)
        router.on_prefill_progress(pe.name, job.n_tokens, rid=rid)
        router.on_busy_until(pe.name, t_done)
        job.result = (first, payload)
        job.meta["prefill_done"] = t_done
        sched.mark_ready(job)
        return True

    def _advance_chunk(self, job: PrefillJob, sched: IterationScheduler,
                       tl: StreamTimeline, router: Router) -> bool:
        """Run one chunk of one scheduled job: lazy task creation (the
        prefix match retires cached tokens immediately), feature supply
        once the barrier chunk is reached, then the jitted suffix
        prefill — with chunk-granular occupancy reported to the Router
        as the chunk ACTUALLY executes (ground truth, not callbacks)."""
        if job.meta.get("monolithic"):
            return self._advance_monolithic(job, sched, tl, router)
        pname = self.prefill_engine.name
        rid = str(job.req.request_id)
        if job.task is None:
            job.task = self.prefill_engine.start_prefill_task(
                job.req, None, job.meta.get("mm_key"),
                defer_features=bool(job.meta.get("needs_feats")))
            self._unpark_queued(job.req)
            # cached-prefix tokens retire at task creation; computed
            # tokens retire per executed chunk below — conservation:
            # cached + sum(chunks) == the on_enqueue total
            router.on_start(pname, job.task.done, rid=rid)
            job.meta["chunk_times"] = list(self.cost.chunk_prefill_times(
                job.n_tokens, job.task.planned_chunk_tokens(),
                cached_prefix=job.task.done))
        task = job.task
        needed_feats = task.needs_features_next()
        if needed_feats and job.meta.get("needs_feats") \
                and job.meta.get("mm_feats") is None:
            if not self._fetch_features_continuous(job, sched, tl):
                return False
        if needed_feats and job.meta.get("mm_feats") is not None:
            task.supply_features(job.meta["mm_feats"])
        try:
            computed = task.run_chunk()
        except PoolExhausted:
            # allocator raised before any mutation: stall + retry after
            # decode drain / admission frees prefill pool pages
            sched.note_stall(job, "pool")
            return False
        except BaseException:
            task.abort()
            raise
        times = job.meta["chunk_times"]
        dur = times.pop(0) if times else 0.0
        nb = job.ready_at
        if needed_feats:
            nb = max(nb, job.feature_ready_at)
        t_done = tl.charge_prefill(dur, not_before=nb)
        router.on_prefill_progress(pname, computed, rid=rid)
        router.on_busy_until(pname, t_done)
        if task.finished:
            job.result = task.finish()
            job.meta["prefill_done"] = t_done
            sched.mark_ready(job)
        return True

    def _admit_with_faults(self, job: PrefillJob, req: Request, payload,
                           first: int, append_token: bool,
                           sched: IterationScheduler,
                           tl: StreamTimeline) -> Optional[Engine]:
        """Admit one ready job through the fault plane WITHOUT blocking
        the iteration on a synchronous retry loop. Each admission pass
        makes ONE delivery attempt of the whole plan; a transfer fault
        parks the job at the ready-queue head with a ``retry_at`` clock
        (capped backoff, charged to the request's retry component as a
        dependency edge — the decode device is not busy waiting) and the
        plan composes around it. On policy exhaustion the serial arm
        fires: full grouped retry + fresh replan of missing groups; if
        THAT fails, TransferError propagates and the caller records the
        loss. Returns None when parked."""
        p = self._build_kv_plan(req, payload)
        rid = req.request_id
        attempt = job.meta.get("xfer_attempts", 0) + 1
        if not self.recovery or attempt >= self.retry.max_attempts:
            # the last word: the grouped retry/replan arm (recovery off:
            # single attempt, no replan — the loss baseline)
            p, rec = self.cost.recover_transfer(
                p, self.injector,
                self.retry if self.recovery else NO_RETRY,
                key=(rid, "replan"), replan=self.recovery)
            self._count_transfer_recovery(rec)
            return self._insert_with_plan(req, payload, first, p, rec,
                                          append_token)
        one_shot = RetryPolicy(max_attempts=1, jitter=0.0,
                               seed=self.retry.seed)
        try:
            p, rec = self.cost.recover_transfer(
                p, self.injector, one_shot, key=(rid, attempt),
                replan=False)
        except TransferError:
            job.meta["xfer_attempts"] = attempt
            base = max(tl.t_prefill, job.meta.get("prefill_done", 0.0))
            nxt = self.retry.next_retry_at(base, attempt, key=rid)
            back = nxt - base
            self.metrics.counter("recovery_retries_total",
                                 site="transfer").inc()
            self.metrics.counter("retry_time_seconds_total",
                                 site="transfer").inc(back)
            self.metrics.counter("sched_retry_parks_total",
                                 engine=self.prefill_engine.name).inc()
            self.acc.sync()
            t0 = self.acc.now
            self.acc.advance(back, rid, "retry")
            if self.tracer.enabled:
                self.tracer.add("retry.transfer", t0, self.acc.now,
                                track="router", request_id=rid,
                                attempt=attempt)
            sched.park_ready(job, nxt)
            return None
        self._count_transfer_recovery(rec)
        return self._insert_with_plan(req, payload, first, p, rec,
                                      append_token)

    def _harvest_reroutes(self, sched: IterationScheduler,
                          tl: StreamTimeline, router: Router) -> None:
        """Scheduler-visible crash/swap-loss recovery: every harvested
        request re-enters the iteration loop as a fresh ``PrefillJob``
        over ``prompt + output_tokens[:-1]`` (the prefix cache keeps the
        re-prefill cheap); at admission the ORIGINAL request resumes
        decode on a survivor with ``append_token=False`` — bit-identical
        greedy resume, no global drain, other requests keep stepping."""
        while self._reroute_queue:
            req = self._reroute_queue.pop(0)
            seq = list(req.prompt_tokens) + list(req.output_tokens[:-1])
            shadow = Request(prompt_tokens=seq, max_new_tokens=1,
                             mm_payload=req.mm_payload,
                             mm_tokens=req.mm_tokens, mm_pos=req.mm_pos,
                             priority=req.priority)
            # the shadow prefill's charges (store retries, transfer
            # exposure) bill the original request's ledger entry
            self.acc.alias(shadow.request_id, req.request_id)
            self.metrics.counter("continuous_reroute_jobs_total").inc()
            job = self._submit_continuous(shadow, sched, tl, router)
            job.meta["resume"] = (req, int(req.output_tokens[-1]))

    def _harvest_engine_lost(self, eng: Engine,
                             sched: Optional[IterationScheduler]) -> None:
        """Reconcile one engine's swap-loss casualties with the
        scheduler's live window: requests the ENGINE could not rebuild
        (multimodal feature embeddings are not retained; cross-attn
        decoders have no suffix step) re-enter the waiting queue as
        re-prefill jobs instead of vanishing — the cluster holds what
        the engine lost (payload bytes, encode recompute). Without
        recovery (or on the serial driver) they surface in
        ``report.lost`` exactly as before."""
        while eng.lost:
            lost = eng.lost.pop(0)
            if sched is not None and self.recovery and lost.output_tokens:
                lost.killed = False
                self.metrics.counter("continuous_harvests_total",
                                     source="swap_lost").inc()
                self._park_queued(lost)
                self._reroute_queue.append(lost)
            else:
                self.report.lost.append(lost)
                self.acc.close(lost.request_id)

    def _decode_iteration(self, done: List[Request], tl: StreamTimeline,
                          router: Router,
                          sched: Optional[IterationScheduler] = None) -> bool:
        """One lock-step decode iteration across every live instance —
        instances are separate devices, so the modeled stream advances
        by the SLOWEST instance's step, not the sum."""
        durs = []
        stepped = False
        for i in self.live_decode_indices():
            eng = self.decode_engines[i]
            if not (eng.n_active or eng.preempted):
                continue
            stepped = True
            if eng.n_active:
                batch = eng.n_active
                kv = sum(r.total_prompt_len + len(r.output_tokens)
                         for r in eng.slots if r is not None) / batch
                durs.append(self.cost.decode_step_time(batch, kv))
            for r, _t, d in eng.decode_step():
                if d:
                    done.append(r)
                    router.on_decode_leave(eng.name)
                    self.acc.close(r.request_id,
                                   n_output_tokens=len(r.output_tokens))
            for pr in eng.preempted:
                self.acc.set_state(pr.req.request_id, "queue")
            for r in eng.slots:
                if r is not None:
                    self.acc.set_state(r.request_id, "compute")
            self._harvest_engine_lost(eng, sched)
        if durs:
            tl.charge_decode(max(durs))
        return stepped

    def run_continuous(self, reqs: List[Request], *,
                       max_steps: int = 100_000,
                       max_live_prefills: Optional[int] = None,
                       chunk_budget_tokens: Optional[int] = None,
                       adaptive_chunking: bool = False,
                       on_step=None) -> List[Request]:
        """Serve ``reqs`` with iteration-level (continuous) batching:
        every device step executes one scheduler-produced
        :class:`BatchPlan` — ready prefill chunks from DIFFERENT
        requests interleave on the prefill stream, finished prefills
        admit into free decode slots (evicting via the engine's
        ``pick_preemption_victim`` path under pool pressure), and all
        active decodes advance lock-step — while a per-stage
        :class:`StreamTimeline` tracks the modeled makespan and a
        ground-truth :class:`Router` sees chunk-granular occupancy.
        Greedy outputs are bit-identical to the serial ``submit`` +
        ``run_until_done`` path: both drivers execute the same
        ``PrefillTask`` chunk sequence and the same jitted forwards.

        The loop composes with the fault plane end-to-end: decode
        crashes harvest in-flight work back into the scheduler as
        re-prefill jobs, transfer faults park the failed admission
        behind a ``retry_at`` barrier, store faults take the §3.2
        retry/recompute arms as schedulable work, and swap losses the
        engine cannot rebuild re-enter ``waiting``. Completed greedy
        outputs stay bit-identical to the zero-fault run; ``lost`` is
        the only other exit. ``on_step(step)`` (when given) runs after
        every iteration — tests hook per-iteration leak audits there."""
        pe = self.prefill_engine
        tl = StreamTimeline()
        self.continuous_timeline = tl
        specs = [InstanceSpec(e.name, ("E",)) for e in self.encode_engines]
        specs.append(InstanceSpec(pe.name, ("P",)))
        specs += [InstanceSpec(e.name, ("D",)) for e in self.decode_engines]
        router = Router(Deployment("continuous", tuple(specs), len(specs)))
        if pe.prefix_cache is not None:
            router.register_prefix_cache(pe.name, pe.prefix_cache)
        self.router = router
        if max_live_prefills is None:
            if pe.paged:
                # size the live window to what the prefill pool can
                # actually hold in-flight at once (worst case: every
                # live task grows to max_len) — interleaving more would
                # only stall on alloc
                per_req = max(1, pe.max_len // pe.page_size)
                max_live_prefills = min(
                    4, max(1, (pe.pool.n_pages - 1) // per_req))
            else:
                # dense engines hold no pool pages mid-prefill
                # (monolithic jobs): the window only bounds fairness
                max_live_prefills = 4
        sched = IterationScheduler(max_live_prefills=max_live_prefills,
                                   chunk_budget_tokens=chunk_budget_tokens,
                                   adaptive_chunking=adaptive_chunking)
        # the engine's page_holders audits scheduler-held payloads
        # (ready-but-unadmitted prefills) through this reference; the
        # cluster-level handle lets benches/tests read step and stall
        # counts after the drain
        pe.scheduler = sched
        self.continuous_scheduler = sched
        for req in reqs:
            self.acc.open(req.request_id)
            self._submit_continuous(req, sched, tl, router)
        done: List[Request] = []
        steps = 0
        while (sched.has_work or self._reroute_queue
               or any(self.decode_engines[i].n_active
                      or self.decode_engines[i].preempted
                      for i in self.live_decode_indices())):
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"continuous drain made no progress in {max_steps} "
                    f"steps (stalls: {sched.stall_counts})")
            # mid-iteration failure domains first: a decode instance may
            # crash between any two steps — its in-flight + preempted
            # requests re-enter the scheduler as re-prefill jobs while
            # everything else keeps stepping (no global drain)
            if self.faults is not None:
                self._maybe_crash(steps)
            if self._reroute_queue:
                self._harvest_reroutes(sched, tl, router)
            free = sum(len(self.decode_engines[i].free_slots())
                       for i in self.live_decode_indices())
            active = sum(self.decode_engines[i].n_active
                         + len(self.decode_engines[i].preempted)
                         for i in self.live_decode_indices())
            plan = sched.plan(now=tl.t_prefill, free_slots=free,
                              active_decode=active)
            progressed = 0
            n_admitted = n_chunked = 0
            with self.tracer.span("sched.step", track="router",
                                  step=plan.step,
                                  n_chunks=len(plan.chunks),
                                  n_admit=len(plan.admit)):
                for job in plan.admit:
                    first, payload = job.result
                    # a crash-harvested job resumes the ORIGINAL request
                    # on the survivor: re-prefilled KV + insert with
                    # append_token=False at the exact decode position
                    resume = job.meta.get("resume")
                    req = resume[0] if resume is not None else job.req
                    tok = resume[1] if resume is not None else first
                    append = resume is None
                    try:
                        if self.faults is not None:
                            engine = self._admit_with_faults(
                                job, req, payload, tok, append, sched, tl)
                            if engine is None:
                                continue      # parked behind retry_at
                        else:
                            engine = self.transfer_and_insert(
                                req, payload, tok, append_token=append)
                    except (NoFreeSlot, PoolExhausted):
                        # insert raises before any mutation; the payload
                        # stays with the job for the next attempt
                        self.report.admission_denials += 1
                        sched.requeue_ready(job)
                        continue
                    except TransferError:
                        # retry + grouped replan exhausted (or recovery
                        # off): surface the loss — never a silent drop
                        if self.paged:
                            pe.release_payload(payload)
                        req.killed = True
                        self.report.lost.append(req)
                        self.acc.close(req.request_id)
                        progressed += 1
                        continue
                    if resume is not None:
                        self.report.reroutes += 1
                    p = self.report.kv_plans[-1]
                    # KV-transfer exposure is handshake round-trip
                    # latency, not link occupancy (wire bytes move in
                    # microseconds): it gates THIS request's decode
                    # join but does not keep the Decode device busy.
                    # The serial driver blocks on each transfer, so the
                    # fused baseline still pays it as device time. A
                    # parked job's retry_at barrier gates the join too.
                    tl.charge_decode(
                        0.0,
                        not_before=max(job.meta.get("prefill_done", 0.0),
                                       job.retry_at)
                        + max(0.0, p.exposed_latency))
                    router.on_decode_join(engine.name)
                    n_admitted += 1
                    progressed += 1
                for job in plan.chunks:
                    if self._advance_chunk(job, sched, tl, router):
                        n_chunked += 1
                        progressed += 1
                decoded = plan.decode and self._decode_iteration(
                    done, tl, router, sched)
                if decoded:
                    progressed += 1
            # same scheduler telemetry the fused-engine execute_plan
            # emits, labeled on the Prefill instance driving the loop
            M = self.metrics
            M.counter("sched_steps_total", engine=pe.name).inc()
            if n_chunked:
                M.counter("sched_chunks_total",
                          engine=pe.name).inc(n_chunked)
            if n_admitted:
                M.counter("sched_admissions_total",
                          engine=pe.name).inc(n_admitted)
            if n_chunked and (n_admitted or decoded):
                M.counter("sched_mixed_steps_total", engine=pe.name).inc()
            if not progressed:
                # nothing executed: either some job waits on a FUTURE
                # arrival (jump the modeled clock to the earliest one —
                # a pool-stalled job's elapsed barrier must not mask a
                # parked job's retry_at, or the retry never matures and
                # its payload pages deadlock the pool), or the prefill
                # pool is deadlocked by partial in-flight tasks (abort
                # the youngest and requeue it)
                t = sched.next_barrier_time(after=tl.t_prefill)
                if t is not None:
                    tl.t_prefill = t
                elif not self._restart_one_prefill(sched):
                    raise RuntimeError(
                        f"continuous scheduler deadlock at step "
                        f"{plan.step} (stalls: {sched.stall_counts})")
            self.acc.sync()
            for eng in self.decode_engines:
                eng.drain_notes()
            pe.drain_notes()
            if not sched.has_prefill_work:
                # prefill stream drained: collapse the Router's stale
                # busy_until so the replica reads idle again
                router.on_idle(pe.name, tl.t_prefill)
            if on_step is not None:
                on_step(steps)
        self._finalize(done)
        return done

"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

[arXiv:2401.04088] — SWA window 4096 caps decode KV, so long_500k runs
for this arch (sub-quadratic decode memory).
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    pattern=(LayerSpec("swa", "moe"),),
    moe=MoEConfig(n_experts=8, top_k=2),
    sliding_window=4096,
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088",
)

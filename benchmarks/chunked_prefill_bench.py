"""Chunked-prefill benchmark: long-prompt prefill window + streaming TTFT.

Two measurements, snapshotted to BENCH_chunked_prefill.json:

1. REAL engine (smollm reduced): a long prompt served monolithically vs
   in chunks. Chunking shrinks the in-flight prefill window — the tokens
   one forward pass materializes (activation/score memory is O(window))
   — from the padded prompt to one chunk, verifies greedy-token parity,
   and audits the page pool (identical KV page footprint, zero leaks:
   later chunks attend over earlier pages, so nothing is freed early).

2. MODELED TTFT (openpangu-7b-vl on the RDMA cross-node profile): the
   serialized baseline (what a monolithic engine does today — prefill,
   THEN one-shot transfer) vs the chunked streaming schedule
   (kv_transfer.plan_chunked) where chunk k's pages ride the link while
   chunk k+1 computes. Asserts the streaming TTFT is strictly lower for
   every prompt >= 4 chunks and that chunk-k transfer overlaps chunk-k+1
   compute in the schedule.
"""
from __future__ import annotations

import json
import os
import time
from typing import List


def bench_chunked_prefill() -> List[str]:
    import jax

    from repro.configs import get_config
    from repro.core.costmodel import RDMA, CostModel
    from repro.core.kv_transfer import plan, plan_chunked
    from repro.models.model import init_params
    from repro.serving.engine import Engine
    from repro.serving.request import Request

    rows = ["chunked_prefill,value,derived"]
    snap = {}

    # ---- 1. real engine: window + parity + page audit ----------------------
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    page, max_len, chunk, prompt_len = 16, 256, 64, 192
    prompt = list(range(100, 100 + prompt_len))
    snap["config"] = {"model": "smollm-135m.reduced", "page_size": page,
                      "max_len": max_len, "prefill_chunk": chunk,
                      "prompt_tokens": prompt_len}

    def prefill(chunked: bool):
        eng = Engine(cfg, params, max_batch=1, max_len=max_len, paged=True,
                     page_size=page, chunked_prefill=chunked,
                     prefill_chunk=chunk)
        for _ in range(2):  # warm the jit buckets
            eng.release_payload(eng.prefill_request(
                Request(prompt_tokens=list(prompt), max_new_tokens=1))[1])
        t0 = time.perf_counter()
        first, payload = eng.prefill_request(
            Request(prompt_tokens=list(prompt), max_new_tokens=1))
        dt = time.perf_counter() - t0
        return eng, first, payload, dt

    m_eng, m_first, m_payload, m_dt = prefill(False)
    c_eng, c_first, c_payload, c_dt = prefill(True)
    assert m_first == c_first, "chunked prefill must be token-exact"
    assert len(c_payload.chunks) == prompt_len // chunk
    # prefill window: tokens one forward materializes (activation proxy)
    snap["window_tokens_monolithic"] = max_len     # prompt padded to max_len
    snap["window_tokens_chunked"] = chunk
    snap["window_reduction"] = round(max_len / chunk, 2)
    snap["peak_pages_monolithic"] = m_eng.pool.peak_used
    snap["peak_pages_chunked"] = c_eng.pool.peak_used
    snap["prefill_wall_monolithic_s"] = round(m_dt, 4)
    snap["prefill_wall_chunked_s"] = round(c_dt, 4)
    for eng, payload in ((m_eng, m_payload), (c_eng, c_payload)):
        eng.assert_no_page_leaks(extra_holders=[payload.page_ids])
        eng.release_payload(payload)
        eng.assert_no_page_leaks()
    snap["leaked_pages"] = 0
    # unified metrics registry of the chunked engine (prefill token
    # counters, pool occupancy gauges) — the common bench telemetry key
    snap["telemetry"] = c_eng.metrics.snapshot()
    rows.append(f"window_tokens,{chunk},vs_{max_len}_monolithic_"
                f"{max_len / chunk:.0f}x_smaller")
    rows.append(f"peak_pages,{c_eng.pool.peak_used},"
                f"monolithic_{m_eng.pool.peak_used}_same_kv_footprint")

    # ---- 2. modeled TTFT: serialized vs streaming --------------------------
    big = get_config("openpangu-7b-vl")
    cost = CostModel(big, RDMA, page_tokens=16)
    C = 1024
    snap["model_ttft"] = {"model": "openpangu-7b-vl", "hw": "RDMA",
                          "chunk_tokens": C, "prompts": {}}
    for L in (2048, 4096, 8192, 16384):
        toks = [C] * (L // C) + ([L % C] if L % C else [])
        per_tok = cost.kv_bytes_per_token()
        ch = plan_chunked(chunk_bytes=[c * per_tok for c in toks],
                          chunk_compute=cost.chunk_prefill_times(L, toks),
                          handshake=cost.hw.handshake,
                          link_bw=cost.hw.link_bw,
                          page_bytes=cost.kv_page_bytes())
        ser = plan("one_shot", n_layers=big.n_layers,
                   bytes_per_layer=cost.kv_bytes(L) / big.n_layers,
                   per_layer_compute=cost.per_layer_prefill_time(L),
                   handshake=cost.hw.handshake, link_bw=cost.hw.link_bw,
                   page_bytes=cost.kv_page_bytes_per_layer())
        if len(toks) >= 4:
            assert ch.total_done < ser.total_done, \
                f"streaming must beat serialized at {L} tokens"
            # chunk-k transfer in flight while chunk-k+1 computes
            assert any(g.t_send < ch.prefill_end for g in ch.groups), \
                "no transfer overlapped prefill compute"
        snap["model_ttft"]["prompts"][str(L)] = {
            "ttft_serialized_ms": round(ser.total_done * 1e3, 2),
            "ttft_chunked_ms": round(ch.total_done * 1e3, 2),
            "exposed_transfer_ms": round(ch.exposed_latency * 1e3, 2),
            "overlap_ratio": round(ch.overlap_ratio, 4),
        }
        rows.append(f"ttft_prompt{L},{ch.total_done * 1e3:.1f}ms,"
                    f"serialized_{ser.total_done * 1e3:.1f}ms")

    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_chunked_prefill.json")
    with open(os.path.abspath(out), "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows


if __name__ == "__main__":
    for row in bench_chunked_prefill():
        print(row)

"""Synthetic data pipeline (token streams + multimodal stubs).

A deterministic, seedable generator standing in for a tokenized corpus:
produces next-token-predictable sequences (affine-recurrence tokens) so a
~100M model visibly learns within a few hundred steps — used by the
training example and integration tests.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def synthetic_batches(cfg: ModelConfig, batch: int, seq: int, steps: int,
                      seed: int = 0, mm: bool = False
                      ) -> Iterator[Dict[str, jnp.ndarray]]:
    """Learnable synthetic LM data: t_{i+1} = (a*t_i + b) % vocab with
    per-sequence (a, b) drawn from a small set — the model must infer the
    recurrence in-context."""
    rng = np.random.default_rng(seed)
    a_set = np.array([3, 5, 7, 11])
    b_set = np.array([1, 2, 17, 31])
    mod = min(cfg.vocab, 64)      # keep the token alphabet small => learnable
    for _ in range(steps):
        a = rng.choice(a_set, size=(batch, 1))
        b = rng.choice(b_set, size=(batch, 1))
        t0 = rng.integers(0, mod, size=(batch, 1))
        toks = [t0]
        for _i in range(seq - 1):
            toks.append((a * toks[-1] + b) % mod)
        tokens = np.concatenate(toks, axis=1).astype(np.int32)
        out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(tokens)}
        if mm and cfg.frontend is not None:
            n = min(cfg.frontend.tokens_per_item, 16)
            out["mm_embeds"] = jnp.asarray(
                rng.normal(0, 0.02, (batch, n, cfg.frontend.feature_dim)),
                jnp.float32)
        if cfg.encoder is not None:
            out["enc_frames"] = jnp.asarray(
                rng.normal(0, 0.02, (batch, cfg.encoder.n_ctx,
                                     cfg.frontend.feature_dim)), jnp.float32)
        yield out

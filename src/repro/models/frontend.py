"""Modality frontend STUBS (the one sanctioned carve-out, see DESIGN.md).

The ViT / conv-codec trunk is not implemented; these helpers produce
deterministic pseudo-embeddings of the right shape from raw input bytes /
arrays, standing in for precomputed patch/frame features. The *projector*
into d_model is a real learned parameter (``params['projector']``).

``encode_tokens_for_image(resolution)`` mirrors the paper's Table 3 token
counts so the serving simulator and MM Store see realistic payload sizes.
"""
from __future__ import annotations

import hashlib
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

# (H, W) -> n tokens, matching the paper's Table 3 for openPangu-7B-VL.
PAPER_RESOLUTION_TOKENS = {
    (280, 280): 100,
    (560, 560): 400,
    (640, 960): 529,
    (720, 1280): 1196,
    (1080, 1920): 2691,
    (4096, 3112): 16206,
}


def encode_tokens_for_image(resolution: Tuple[int, int],
                            patch: int = 28, merge: int = 1) -> int:
    """Vision-token count for an image; follows the paper's scaling."""
    if resolution in PAPER_RESOLUTION_TOKENS:
        return PAPER_RESOLUTION_TOKENS[resolution]
    h, w = resolution
    return max(1, (h // patch) * (w // patch) // max(merge, 1))


def content_hash(payload: bytes) -> str:
    """Hash key for the MM Store (paper §3.2: hash of multimodal input)."""
    return hashlib.sha256(payload).hexdigest()


def stub_embeddings(cfg: ModelConfig, payload: bytes, n_tokens: int = 0,
                    dtype=jnp.float32) -> jax.Array:
    """Deterministic pseudo patch/frame embeddings for one item.

    Shape (n_tokens, feature_dim). Deterministic in the payload so MM Store
    cache hits return bit-identical features (tested).
    """
    fe = cfg.frontend
    assert fe is not None, f"{cfg.name} has no frontend"
    n = n_tokens or fe.tokens_per_item
    seed = int.from_bytes(hashlib.sha256(payload).digest()[:4], "big")
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (n, fe.feature_dim), dtype) * 0.02


def feature_bytes(cfg: ModelConfig, n_tokens: int, dtype_bytes: int = 2) -> int:
    """Size of the E->P payload for n vision/audio tokens (post-projector,
    d_model-wide — what actually travels per the paper's Table 3)."""
    return n_tokens * cfg.d_model * dtype_bytes


def mm_key_run(key: str, n: int) -> list:
    """Pseudo-token run standing in for a multimodal segment in the radix
    prefix-cache key: (mm-content-hash, token-run).

    Deterministic in the content hash, so the same image always expands to
    the same run (identical image + prompt => prefix-cache hit over the mm
    segment, composing MM Store dedup with KV reuse). Tokens are NEGATIVE
    ints, disjoint from any real vocab id — they are never embedded, only
    matched; the engine feeds 0 at mm positions and overwrites those
    embeddings with the projected features.
    """
    seed = int.from_bytes(hashlib.sha256(key.encode()).digest()[:8], "big")
    out, x = [], seed
    for _ in range(n):
        # 64-bit LCG (Knuth MMIX constants): cheap, deterministic spread
        x = (x * 6364136223846793005 + 1442695040888963407) & ((1 << 64) - 1)
        out.append(-1 - (x >> 33))
    return out

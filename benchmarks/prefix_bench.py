"""Prefix-cache benchmark: shared-system-prompt workload.

N requests share one common prefix (the "system prompt") and append a
unique suffix — the canonical high-concurrency chat shape. Baseline is
the plain paged engine (every request prefills its whole prompt);
treatment is the same engine with the radix prefix cache, which prefills
only the unique suffix after the first request.

Reports token-weighted hit rate, prefill tokens computed vs requested,
prefill wall-clock vs the paged baseline, and the leak audit (pool usage
must equal the live slots' pages + the tree's retentions after drain).
Emits a BENCH_prefix_cache.json snapshot next to the repo root so the
perf trajectory is recorded per PR.
"""
from __future__ import annotations

import json
import os
import time
from typing import List

import jax


def _drain(eng):
    while eng.n_active:
        eng.decode_step()


def _serve_prefill(eng, prompt):
    """Prefill + insert + drain one request; returns prefill seconds."""
    from repro.serving.request import Request

    r = Request(prompt_tokens=list(prompt), max_new_tokens=2)
    t0 = time.perf_counter()
    first, payload = eng.prefill_request(r)
    dt = time.perf_counter() - t0
    eng.insert(r, payload, first)
    _drain(eng)
    return dt


def bench_prefix_cache() -> List[str]:
    from repro.configs import get_config
    from repro.models.model import init_params
    from repro.serving.engine import Engine

    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    page, max_len = 16, 128
    n_requests, prefix_len, suffix_len = 24, 96, 8
    shared = list(range(100, 100 + prefix_len))
    prompts = [shared + [5000 + 100 * i + j for j in range(suffix_len)]
               for i in range(n_requests)]

    rows = ["prefix_cache,value,derived"]
    snap = {"config": {"model": "smollm-135m.reduced", "page_size": page,
                       "max_len": max_len, "n_requests": n_requests,
                       "prefix_tokens": prefix_len,
                       "suffix_tokens": suffix_len}}

    def run(prefix: bool) -> tuple:
        eng = Engine(cfg, params, max_batch=4, max_len=max_len, paged=True,
                     page_size=page, prefix_cache=prefix,
                     n_pool_pages=1 + 24 * (max_len // page))
        # warm every jit bucket outside the timed region: the cold-path
        # trace (first serve) and the hit-path suffix bucket + CoW copy
        # (re-serving the same prompt matches all but the last token)
        _serve_prefill(eng, prompts[0])
        _serve_prefill(eng, prompts[0])
        wall = sum(_serve_prefill(eng, p) for p in prompts[1:])
        return eng, wall

    base_eng, base_wall = run(prefix=False)
    pfx_eng, pfx_wall = run(prefix=True)

    computed = pfx_eng.prefill_tokens_computed
    total = pfx_eng.prefill_tokens_total
    stats = pfx_eng.prefix_cache.stats
    assert stats.hit_rate > 0, "shared-prefix workload must hit the cache"
    assert total >= 2 * computed, \
        f"expected >=2x prefill-token reduction, got {total}/{computed}"
    snap["prefill_tokens_total"] = total
    snap["prefill_tokens_computed"] = computed
    snap["prefill_token_reduction"] = round(total / max(computed, 1), 2)
    snap["hit_rate"] = round(stats.hit_rate, 4)
    snap["baseline_wall_s"] = round(base_wall, 3)
    snap["prefix_wall_s"] = round(pfx_wall, 3)
    snap["wall_speedup"] = round(base_wall / max(pfx_wall, 1e-9), 2)

    # leak audit: after draining, used pages == tree retentions exactly
    pfx_eng.assert_no_page_leaks()
    base_eng.assert_no_page_leaks()
    retained = len(pfx_eng.prefix_cache.retained_pages())
    assert pfx_eng.pool.n_used == retained, \
        f"leak: {pfx_eng.pool.n_used} used != {retained} retained"
    assert base_eng.pool.n_used == 0
    snap["leaked_pages"] = pfx_eng.pool.n_used - retained
    # unified metrics registry of the prefix-cache engine (hit-rate
    # gauge, prefill token counters) — the common bench telemetry key
    snap["telemetry"] = pfx_eng.metrics.snapshot()

    rows.append(f"hit_rate,{stats.hit_rate:.3f},"
                f"{stats.hits}/{stats.lookups}_lookups")
    rows.append(f"prefill_tokens,{computed},of_{total}_requested_"
                f"{total / max(computed, 1):.1f}x_reduction")
    rows.append(f"prefill_wall_s,{pfx_wall:.3f},"
                f"{base_wall / max(pfx_wall, 1e-9):.2f}x_vs_paged_baseline")
    rows.append(f"leaked_pages,0,used_{pfx_eng.pool.n_used}"
                f"==tree_{retained}")

    out = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_prefix_cache.json")
    with open(os.path.abspath(out), "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows


if __name__ == "__main__":
    for row in bench_prefix_cache():
        print(row)

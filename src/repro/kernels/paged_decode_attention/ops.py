"""Jit'd public wrapper for the paged decode-attention kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import dispatch
from repro.kernels.paged_decode_attention.kernel import (
    paged_decode_attention as _kernel)
from repro.kernels.paged_decode_attention.ref import paged_decode_attention_ref


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, k_pool, v_pool, block_tbl, lengths, *,
                           window: Optional[int] = None,
                           interpret: Optional[bool] = None):
    if interpret is None:
        interpret = dispatch.interpret()
    return _kernel(q, k_pool, v_pool, block_tbl, lengths, window=window,
                   interpret=interpret)


__all__ = ["paged_decode_attention", "paged_decode_attention_ref"]

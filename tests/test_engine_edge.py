"""Edge cases: engine capacity, enc-dec serving, simulator breakdown,
preemption corner cases (disabled => old kill behavior; sole-victim
denial; resume across prefix-cache eviction)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.cluster import EPDCluster
from repro.core.simulator import SHAREGPT_4O, simulate
from repro.models.model import init_params
from repro.serving.engine import Engine
from repro.serving.kv_pool import PoolExhausted
from repro.serving.request import Request


def test_whisper_epd_serving():
    """Enc-dec (audio) arch through the full disaggregated pipeline."""
    cfg = get_config("whisper-base").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    cluster = EPDCluster(cfg, params, max_batch=2, max_len=48)
    reqs = [Request(prompt_tokens=[1, 2, 3], max_new_tokens=4,
                    mm_payload=b"audio-%d" % i, mm_tokens=0)
            for i in range(2)]
    for r in reqs:
        cluster.submit(r)
    done = cluster.run_until_done()
    assert len(done) == 2
    assert all(len(r.output_tokens) == 4 for r in done)


def test_engine_slot_reuse():
    """Slots free on completion and are reusable for new requests."""
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_len=48)
    for wave in range(3):
        reqs = [Request(prompt_tokens=[5 + wave, 6, 7], max_new_tokens=3)
                for _ in range(2)]
        for r in reqs:
            first, caches = eng.prefill_request(r)
            eng.insert(r, caches, first)
        while eng.n_active:
            eng.decode_step()
        assert all(len(r.output_tokens) == 3 for r in reqs)
    assert eng.free_slots() == [0, 1]


def test_engine_rejects_overlong_prompt():
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=1, max_len=16)
    with pytest.raises(ValueError, match="exceeds"):
        eng.prefill_request(Request(prompt_tokens=list(range(40))))


def test_preemption_disabled_preserves_kill_behavior():
    """Without preemption=True nothing is preempted: growth exhaustion
    raises the typed PoolExhausted exactly like before, host/device
    bookkeeping stays consistent, and no request is parked."""
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_len=32, paged=True,
                 page_size=8, n_pool_pages=5)      # 4 usable pages
    reqs = [Request(prompt_tokens=list(range(2, 18)), max_new_tokens=30)
            for _ in range(2)]
    for r in reqs:
        f, p = eng.prefill_request(r)
        eng.insert(r, p, f)
    with pytest.raises(PoolExhausted):
        while eng.n_active:
            eng.decode_step()
    assert eng.preempt_count == 0
    assert not eng.preempted
    assert all(r.n_preempts == 0 for r in reqs)
    # accounting intact: host page lists agree with the allocator
    assert sum(len(p) for p in eng._slot_pages if p is not None) \
        == eng.pool.n_used


def test_sole_active_victim_denies_instead_of_thrashing():
    """When the only possible victim is the only active request —
    growth for itself, or admission of a newcomer — the engine denies
    the allocation (typed error) instead of swap-thrashing it."""
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_len=32, paged=True,
                 page_size=4, preemption=True, n_pool_pages=4)  # 3 usable
    r = Request(prompt_tokens=list(range(2, 10)), max_new_tokens=20)
    f, p = eng.prefill_request(r)
    eng.insert(r, p, f)
    # its own growth cannot evict it
    with pytest.raises(PoolExhausted):
        while eng.n_active:
            eng.decode_step()
    assert eng.preempt_count == 0
    assert any(s is r for s in eng.slots)          # victim untouched
    # admission of a second request cannot evict the last active either
    src = Engine(cfg, params, max_batch=1, max_len=32, paged=True,
                 page_size=4)
    r2 = Request(prompt_tokens=list(range(40, 48)), max_new_tokens=2)
    f2, p2 = src.prefill_request(r2)
    with pytest.raises(PoolExhausted):
        eng.insert(r2, p2, f2)
    assert eng.preempt_count == 0
    assert any(s is r for s in eng.slots)
    src.release_payload(p2)
    src.assert_no_page_leaks()
    eng.assert_no_page_leaks()


def test_resume_after_prefix_eviction_refaults_private_copies():
    """A preempted request whose tree-shared prefix is evicted while
    parked must recompute those pages into private copies on resume —
    not dangle on freed ids — and still match the uninterrupted output."""
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = list(range(2, 20))                    # 18 tokens
    base = Engine(cfg, params, max_batch=2, max_len=64, paged=True,
                  page_size=8)
    r0 = Request(prompt_tokens=list(prompt), max_new_tokens=6)
    f, p = base.prefill_request(r0)
    base.insert(r0, p, f)
    while base.n_active:
        base.decode_step()

    eng = Engine(cfg, params, max_batch=2, max_len=64, paged=True,
                 page_size=8, prefix_cache=True, preemption=True,
                 n_pool_pages=64)
    seed = Request(prompt_tokens=list(prompt), max_new_tokens=2)
    f, p = eng.prefill_request(seed)
    eng.insert(seed, p, f)
    while eng.n_active:
        eng.decode_step()
    r = Request(prompt_tokens=list(prompt), max_new_tokens=6)
    f, p = eng.prefill_request(r)                  # hits the cached prefix
    eng.insert(r, p, f)
    eng.decode_step()
    pr = eng.preempt_slot(next(i for i, s in enumerate(eng.slots)
                               if s is r))
    assert pr.n_shared_pages > 0                   # prefix stayed in tree
    evicted = eng.prefix_cache.evict(eng.pool.n_pages)
    assert evicted >= pr.n_shared_pages            # ...until we drop it
    assert not eng.prefix_cache.retained_pages()
    steps = 0
    while any(s is r for s in eng.slots) or eng.preempted:
        eng.decode_step()
        steps += 1
        assert steps < 100
    assert r.output_tokens == r0.output_tokens
    assert eng.refault_pages_total >= pr.n_shared_pages
    eng.assert_no_page_leaks()


def test_simulator_stage_breakdown_consistency():
    m = simulate(get_config("openpangu-7b-vl"), "E-P-D", SHAREGPT_4O,
                 rate=4.0, n_requests=96, seed=4)
    b = m.stage_breakdown_ms()
    # decomposition covers TTFT: queue + encode + dispatch + prefill ~ TTFT
    total = b["encode_queue"] + b["encode"] + b["dispatch"] + b["prefill"]
    assert total == pytest.approx(m.mean_ttft_ms, rel=0.02)
    for v in b.values():
        assert v >= 0.0


def test_simulator_replicas_balance_load():
    """2 replicas at 2x the rate should roughly match 1 replica at 1x."""
    model = get_config("openpangu-7b-vl")
    one = simulate(model, "(E-P)-D", SHAREGPT_4O, rate=3.0,
                   n_requests=128, seed=6)
    two = simulate(model, "(E-P)-D", SHAREGPT_4O, rate=6.0,
                   n_requests=128, seed=6, replicas=2)
    assert two.n_chips == 2 * one.n_chips
    # per-chip throughput comparable (within queueing noise)
    t1 = one.throughput_tok_s / one.n_chips
    t2 = two.throughput_tok_s / two.n_chips
    assert t2 == pytest.approx(t1, rel=0.25)


def test_idle_decode_step_dispatches_no_forward():
    """Zero active slots: decode_step must return [] WITHOUT dispatching
    the jitted forward or syncing `len` back to host (regression for the
    idle-batch early-out)."""
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_len=48, paged=True,
                 page_size=8)
    calls = {"n": 0}
    real = eng._decode

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    eng._decode = counting
    assert eng.decode_step() == []
    assert calls["n"] == 0
    # with an active slot the forward runs exactly once per step
    r = Request(prompt_tokens=[3, 4, 5], max_new_tokens=4)
    first, caches = eng.prefill_request(r)
    eng.insert(r, caches, first)
    eng.decode_step()
    assert calls["n"] == 1


def test_decode_fills_cache_to_exactly_max_len():
    """Done-check boundary: a request may fill the KV cache to exactly
    max_len (the old `>= max_len - 1` check gave away the last usable
    position). Resident KV after the final step is prompt + decoded
    inputs == max_len, and the token count follows."""
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_len, n_prompt = 16, 5
    eng = Engine(cfg, params, max_batch=1, max_len=max_len, paged=True,
                 page_size=8)
    r = Request(prompt_tokens=list(range(2, 2 + n_prompt)),
                max_new_tokens=100, eos_token=-1)    # length-capped only
    first, caches = eng.prefill_request(r)
    eng.insert(r, caches, first)
    while eng.n_active:
        eng.decode_step()
    # each decode step writes one KV entry (starting at len=n_prompt)
    # until the cache holds exactly max_len tokens
    assert int(jnp.asarray(eng.caches["len"])[0]) == max_len
    # outputs: the prefill token + one per decode step (max_len - n_prompt
    # steps); the old check stopped one step early
    assert len(r.output_tokens) == max_len - n_prompt + 1
    eng.assert_no_page_leaks()

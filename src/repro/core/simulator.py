"""End-to-end EPD-Serve simulator.

Executes a request trace against a deployment topology on the
discrete-event engine, with:

* modality-aware multi-path routing + least-loaded dispatch (scheduler),
* MM Store dedup + E->P async feature prefetching (ep_prefetch),
* P->D hierarchical grouped KV transmission (kv_transfer),
* physical co-location with operator-level interference (colocation),
* stage service times from the roofline cost model (costmodel).

Instance execution semantics:
* every instance runs ONE task at a time (its own serial stream);
* monolithic instances (TP1/TP2, 'PD', 'EP') put Encode/Prefill tasks and
  decode iterations in one queue — E/P tasks take priority, which is the
  vLLM-style behaviour that starves Decode under load (paper §1);
* co-located instances (same ``coloc_group``) run concurrently but pay
  the interference slowdown for whatever their chip-mates execute;
* Decode runs as back-to-back batched iterations, one token per request
  per iteration (continuous batching).

This is the scale model used for the paper's Tables 2/5 and Figs 8-17;
the REAL-compute path (actual JAX engines wired through the same MM
Store / scheduler / transfer planner) lives in repro.core.cluster.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core import colocation
from repro.core.costmodel import CostModel, Hardware, V5E
from repro.core.deployment import Deployment, parse
from repro.core.ep_prefetch import EPPrefetcher
from repro.core.faults import (DEFAULT_RETRY, NO_RETRY, FaultInjector,
                               FaultPlan, RetryPolicy, TransferError)
from repro.core.events import EventLoop
from repro.core.kv_transfer import (emit_spans, plan as kv_plan,
                                    plan_chunked as kv_plan_chunked)
from repro.core.telemetry import (NULL_TRACER, LatencyAccountant,
                                  MetricsRegistry, Tracer, quantile)
from repro.core.mm_store import MMStore
from repro.core.scheduler import (Router, VictimCandidate,
                                  pick_preemption_victim)
from repro.models.frontend import encode_tokens_for_image
from repro.serving.kv_pool import pages_for
from repro.serving.request import Request


# ---------------------------------------------------------------------------
# Workload
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DatasetSpec:
    name: str
    mm_fraction: float
    resolution: Tuple[int, int]
    text_tokens_mean: float
    output_tokens: int = 64
    unique_images: int = 0        # 0 => every image unique (no dedup hits)
    # shared-prefix workload (system prompts / few-shot templates):
    # each request prepends one of `prefix_groups` shared prefixes of
    # `prefix_tokens` tokens to its (unique) tail. 0 => no shared prefixes.
    prefix_groups: int = 0
    prefix_tokens: int = 0


# paper §4.1
SHAREGPT_4O = DatasetSpec("ShareGPT-4o", 1.0, (802, 652), 9.6)
VISUALWEB = DatasetSpec("VisualWebInstruct", 0.5, (1280, 720), 63.1)


def gen_requests(spec: DatasetSpec, n: int, rate: float,
                 seed: int = 0) -> List[Request]:
    """Poisson arrivals at `rate` req/s; modality mix per the dataset."""
    rng = random.Random(seed)
    reqs = []
    t = 0.0
    mm_tokens = encode_tokens_for_image(spec.resolution)
    for i in range(n):
        t += rng.expovariate(rate)
        is_mm = rng.random() < spec.mm_fraction
        text_len = max(1, int(rng.gauss(spec.text_tokens_mean,
                                        spec.text_tokens_mean * 0.3)))
        payload = None
        ntok = 0
        if is_mm:
            img_id = (rng.randrange(spec.unique_images)
                      if spec.unique_images else i)
            payload = f"{spec.name}-img-{img_id}".encode()
            ntok = mm_tokens
        if spec.prefix_groups:
            g = rng.randrange(spec.prefix_groups)
            prompt = ([1_000_000 + g * spec.prefix_tokens + j
                       for j in range(spec.prefix_tokens)]
                      + [2_000_000 + i * 1024 + j for j in range(text_len)])
        else:
            # per-request-unique tokens: without them every prompt would
            # be a literal prefix of every longer one and a prefix-cache
            # run over a legacy dataset would report phantom hits
            prompt = [2_000_000 + i * 1024 + j for j in range(text_len)]
        reqs.append(Request(
            prompt_tokens=prompt,
            max_new_tokens=spec.output_tokens,
            mm_payload=payload, mm_tokens=ntok, t_arrival=t))
    return reqs


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------

@dataclass
class SimConfig:
    deployment: str = "E-P-D"
    kv_scheme: str = "grouped"          # one_shot | layer_wise | grouped
    ep_async: bool = True
    decode_batch_max: int = 512
    replicas: int = 1
    hw: Hardware = V5E
    kv_page_tokens: int = 0             # paged KV pool page size (0 = dense)
    # per-Prefill-instance radix prefix caches + cache-aware routing;
    # prefill service time then covers only the uncached suffix.
    prefix_cache: bool = False
    cache_aware_routing: bool = True    # False: least-loaded only (ablation)
    # capacity of each pool-less sim tree (tokens, LRU-evicted): models a
    # bounded KV pool and keeps long simulations from growing one radix
    # node per unique prompt tail forever
    prefix_cache_tokens: int = 65536
    # chunked prefill + streaming P->D transfer: prefill runs in
    # fixed-size chunks whose KV ships while the next chunk computes
    # (kv_transfer.plan_chunked); prefill occupancy retires pending
    # tokens chunk by chunk (Router.on_prefill_progress). Each extra
    # chunk costs one launch overhead — the price of streaming.
    chunked_prefill: bool = False
    prefill_chunk_tokens: int = 256
    # Decode-side KV capacity + page-level preemption. decode_kv_pages
    # bounds each Decode instance's page pool (0 = unbounded, the
    # legacy behavior); admission then checks pages, not just batch
    # slots. When decode growth overflows the pool mid-stream:
    # preemption=False kills the victim (the pre-preemption baseline),
    # preemption=True swaps it to host (CostModel.swap_time charged in
    # the decode stream) and resumes it when pages free up — same
    # victim policy as the real engine (scheduler.pick_preemption_victim).
    decode_kv_pages: int = 0
    preemption: bool = False
    # failure-domain chaos layer: a seeded FaultPlan arms store-fetch
    # and P->D transfer faults; `retry` is the typed backoff policy the
    # recovery arms charge into latency; fault_recovery=False is the
    # losing baseline (any transfer fault kills the request).
    faults: Optional[FaultPlan] = None
    retry: Optional[RetryPolicy] = None
    fault_recovery: bool = True
    # observability plane (core.telemetry): pass a Tracer to get spans
    # on simulated time, a MetricsRegistry to share counters across
    # runs. None keeps the hot paths allocation-free (NULL_TRACER).
    tracer: Optional[Tracer] = None
    metrics: Optional[MetricsRegistry] = None


@dataclass
class SimMetrics:
    deployment: str
    n_chips: int
    requests: List[Request]
    makespan: float
    mean_ttft_ms: float
    p99_ttft_ms: float
    mean_tpot_ms: float
    p99_tpot_ms: float
    throughput_tok_s: float            # all output tokens / makespan
    store_hit_rate: float
    ep_overlap_ratio: float
    prefix_hit_rate: float = 0.0       # cached prefill tokens / text tokens
    completed_requests: int = 0        # finished with full output
    killed_requests: int = 0           # dropped by decode-OOM (no preemption)
    n_preemptions: int = 0             # page-level preempt/swap events
    # chaos layer: P->D transfer fault recovery accounting
    lost_requests: int = 0             # unrecoverable transfer losses
    transfer_retries: int = 0          # failed group attempts retried
    retry_time_ms: float = 0.0         # modeled backoff + resend time
    # observability: per-request latency attribution (components sum to
    # e2e on simulated time) + the metrics-registry snapshot
    attribution: Optional[Dict] = None
    telemetry: Optional[Dict] = None

    def slo_attainment(self, ttft_ms: float, tpot_ms: float) -> float:
        ok = sum(r.meets_slo(ttft_ms, tpot_ms) for r in self.requests)
        return ok / len(self.requests)

    def stage_breakdown_ms(self) -> Dict[str, float]:
        """Mean per-stage latency decomposition (production observability:
        shows WHERE the TTFT goes per deployment — queueing vs encode vs
        E->P dispatch vs prefill)."""
        agg: Dict[str, float] = {}
        for r in self.requests:
            for k, v in r.stage_breakdown().items():
                agg[k] = agg.get(k, 0.0) + v * 1e3
        return {k: v / len(self.requests) for k, v in agg.items()}

    def effective_throughput(self, ttft_ms: float, tpot_ms: float,
                             per_chip: bool = True) -> float:
        toks = sum(len(r.output_tokens) for r in self.requests
                   if r.meets_slo(ttft_ms, tpot_ms))
        t = toks / self.makespan if self.makespan > 0 else 0.0
        return t / self.n_chips if per_chip else t


class _Instance:
    def __init__(self, sim: "Simulator", spec):
        self.sim = sim
        self.spec = spec
        self.queue: List[Tuple[str, Request]] = []    # E / P tasks
        self.decode_batch: Dict[int, Tuple[Request, int]] = {}
        self.decode_wait: List[Request] = []
        # page-level preemption: (req, remaining) parked with their KV
        # swapped to host, FIFO resume; marks gate the starvation guard
        self.preempted: List[Tuple[Request, int]] = []
        self._resume_marks: Dict[int, int] = {}
        self._swap_penalty = 0.0      # host-link time owed by the next iter
        self._parked_at: Dict[int, float] = {}   # rid -> preempt time (spans)
        self._decode_iters = 0                   # decode-span sampling
        self.busy = False
        self.running_stage: Optional[str] = None

    # ---- task intake ----
    def enqueue(self, stage: str, req: Request) -> None:
        self.queue.append((stage, req))
        self.sim.router.on_enqueue(self.spec.name, req.total_prompt_len,
                                   rid=str(req.request_id))
        self._kick()

    # ---- decode KV-capacity accounting (paged pool model) ----
    def _held_pages(self, req: Request) -> int:
        page = self.sim.cfg.kv_page_tokens or 16
        return pages_for(req.total_prompt_len + len(req.output_tokens), page)

    def _pages_used(self) -> int:
        return sum(self._held_pages(r) for r, _ in self.decode_batch.values())

    def _can_admit(self, req: Request) -> bool:
        """Preemption-aware decode admission: a request joins the batch
        only when both a batch slot AND its KV pages are available —
        overflow waits instead of being force-fed into a full pool."""
        if len(self.decode_batch) >= self.sim.cfg.decode_batch_max:
            return False
        cap = self.sim.cfg.decode_kv_pages
        return not cap or self._pages_used() + self._held_pages(req) <= cap

    def join_decode(self, req: Request) -> None:
        cap = self.sim.cfg.decode_kv_pages
        if cap and self._held_pages(req) > cap:
            # bigger than the whole pool: unservable at this capacity in
            # EITHER mode — drop it now instead of head-of-line blocking
            # decode_wait forever (preemption can't shrink a request)
            req.killed = True
            req.t_done = self.sim.loop.now
            self.sim.n_killed += 1
            self.sim.metrics.counter("killed_requests_total").inc()
            self.sim.done.append(req)
            self.sim.acc.close(req.request_id, len(req.output_tokens))
            return
        if not self._can_admit(req):
            self.sim.acc.set_state(req.request_id, "queue")
            self.decode_wait.append(req)
            return
        self.decode_batch[req.request_id] = (req, req.max_new_tokens - 1)
        self.sim.acc.set_state(req.request_id, "compute")
        self.sim.router.on_decode_join(self.spec.name)
        self._kick()

    # ---- execution loop ----
    def _kick(self) -> None:
        if not self.busy:
            self._next()

    def _interference(self, stage: str) -> float:
        if self.spec.coloc_group < 0:
            return 1.0
        peers = [i for i in self.sim.instances.values()
                 if i.spec.coloc_group == self.spec.coloc_group
                 and i is not self and i.busy and i.running_stage]
        if not peers:
            return 1.0
        return colocation.stage_slowdown(stage, [p.running_stage for p in peers])

    def _next(self) -> None:
        sim = self.sim
        loop = sim.loop
        if self.queue:
            stage, req = self.queue.pop(0)
            self.busy, self.running_stage = True, stage
            if stage == "E":
                sim.router.on_start(self.spec.name, req.total_prompt_len,
                                    rid=str(req.request_id))
                dur = sim.cost.encode_time(req.mm_tokens, self.spec.chips,
                                           self.spec.tp)
                dur *= self._interference("E")
                req.t_encode_start = loop.now
                sim.acc.set_state(req.request_id, "compute")
                if sim.tracer.enabled:
                    sim.tracer.add("encode", loop.now, loop.now + dur,
                                   track=self.spec.name,
                                   request_id=req.request_id)
                loop.after(dur, lambda: self._finish_encode(req))
            else:
                cached = self._prefix_lookup(req)
                chunk_toks = self._chunk_tokens(req, cached)
                inter = self._interference("P")
                req.t_prefill_start = loop.now
                if chunk_toks is None:
                    sim.router.on_start(self.spec.name,
                                        req.total_prompt_len,
                                        rid=str(req.request_id))
                    dur = sim.cost.prefill_time(
                        req.total_prompt_len, self.spec.chips,
                        self.spec.tp, cached_prefix=cached) * inter
                    self._start_prefill(req, dur, cached, None)
                else:
                    # chunk-granular occupancy: the cached prefix
                    # retires immediately, computed tokens retire as
                    # each chunk finishes
                    rid = str(req.request_id)
                    sim.router.on_start(self.spec.name, cached, rid=rid)
                    times = [t * inter for t in sim.cost.chunk_prefill_times(
                        req.total_prompt_len, chunk_toks, self.spec.chips,
                        self.spec.tp, cached_prefix=cached)]
                    t_end = 0.0
                    name = self.spec.name
                    for c, dt in zip(chunk_toks, times):
                        t_end += dt
                        loop.after(t_end, lambda c=c:
                                   sim.router.on_prefill_progress(
                                       name, c, rid=rid))
                    dur = sum(times)
                    self._start_prefill(req, dur, cached,
                                        (chunk_toks, times))
            sim.router.on_busy_until(self.spec.name, loop.now + dur)
        elif self.decode_batch:
            self.busy, self.running_stage = True, "D"
            batch = len(self.decode_batch)
            kv = sum(r.total_prompt_len + len(r.output_tokens)
                     for r, _ in self.decode_batch.values()) / batch
            dur = sim.cost.decode_step_time(batch, kv, self.spec.chips,
                                            self.spec.tp)
            dur *= self._interference("D")
            # swap traffic owed by preempt/resume events serializes into
            # the decode stream (pages are unusable until the copy lands)
            dur += self._swap_penalty
            self._swap_penalty = 0.0
            self._decode_iters += 1
            if sim.tracer.want_decode_span(self._decode_iters):
                sim.tracer.add("decode.step", loop.now, loop.now + dur,
                               track=self.spec.name, batch=batch)
            loop.after(dur, self._finish_decode_iter)
            sim.router.on_busy_until(self.spec.name, loop.now + dur)
        else:
            self.busy, self.running_stage = False, None
            # drained: collapse the stale busy_until estimate so pick()
            # sees this replica as idle again
            sim.router.on_idle(self.spec.name, loop.now)

    def _chunk_tokens(self, req: Request, cached: float) -> Optional[list]:
        """Computed-token split of this request's prefill into fixed
        chunks, or None when chunked mode is off / the prompt fits in
        one chunk (chunking a single-chunk prompt only adds overhead).
        Mirrors the real engine's fallbacks: multimodal prompts and
        non-attention-only decoders are served monolithically, so the
        sim must not credit them streaming overlap."""
        cfg = self.sim.cfg
        model = self.sim.model
        if not cfg.chunked_prefill:
            return None
        if req.is_multimodal or model.encoder is not None \
                or model.ssm_layers:
            return None
        C = max(1, cfg.prefill_chunk_tokens)
        computed = max(1, int(req.total_prompt_len - cached))
        if computed <= C:
            return None
        out = [C] * (computed // C)
        if computed % C:
            out.append(computed % C)
        return out

    def _prefix_lookup(self, req: Request) -> float:
        """Cached-prefix tokens on THIS instance's radix tree (full pages
        only), recording hit stats and retaining the prompt for future
        requests. 0 for multimodal prompts (token-keyed cache)."""
        sim = self.sim
        cache = sim.router.prefix_caches.get(self.spec.name)
        if cache is None or req.is_multimodal:
            return 0.0
        m = cache.match_and_ref(req.prompt_tokens,
                                cap=len(req.prompt_tokens) - 1)
        cached = (m.n_tokens // cache.page) * cache.page
        cache.insert(req.prompt_tokens)
        sim.prefix_hit_tokens += cached
        sim.prefix_prompt_tokens += len(req.prompt_tokens)
        return float(cached)

    # ---- stage completions ----
    def _finish_encode(self, req: Request) -> None:
        sim = self.sim
        req.t_encode_done = sim.loop.now
        e_block = sim.finish_encode(self, req)
        if e_block > 0:
            sim.loop.after(e_block, self._next)   # sync push blocks E
        else:
            self._next()

    def _start_prefill(self, req: Request, base_dur: float, cached: float,
                       chunked: Optional[tuple]) -> None:
        sim = self.sim
        sim.acc.set_state(req.request_id, "compute")
        if sim.tracer.enabled:
            if chunked is not None:
                t = sim.loop.now
                for k, dt in enumerate(chunked[1]):
                    sim.tracer.add("prefill.chunk", t, t + dt,
                                   track=self.spec.name,
                                   request_id=req.request_id, chunk=k)
                    t += dt
            else:
                sim.tracer.add("prefill", sim.loop.now,
                               sim.loop.now + base_dur,
                               track=self.spec.name,
                               request_id=req.request_id)
        d_inst = sim.pick_decode_instance(req, prefer=self.spec.name)
        if d_inst is self:
            # fused PD: no transfer
            sim.loop.after(base_dur, lambda: self._finish_prefill(
                req, d_inst, join_delay=0.0))
            return
        if chunked is not None:
            # streaming: chunk k's pages ride the link under chunk k+1's
            # compute; a cached prefix ships at t=0 (zero compute).
            # Segment bytes are token-proportional slices of the SAME
            # kv_bytes total the serialized baseline plans (sliding-
            # window cap + SSM state included), so the A/B compares
            # schedules, not payload models.
            chunk_toks, times = chunked
            total_toks = cached + sum(chunk_toks)
            per_tok = sim.cost.kv_bytes(req.total_prompt_len) / total_toks
            p = kv_plan_chunked(
                chunk_bytes=[cached * per_tok]
                + [c * per_tok for c in chunk_toks],
                chunk_compute=[0.0] + list(times),
                handshake=sim.cfg.hw.handshake,
                link_bw=sim.cfg.hw.link_bw,
                page_bytes=sim.cost.kv_page_bytes())
        else:
            p = kv_plan(sim.cfg.kv_scheme,
                        n_layers=sim.model.n_layers,
                        bytes_per_layer=sim.cost.kv_bytes(
                            req.total_prompt_len) / sim.model.n_layers,
                        per_layer_compute=base_dur / sim.model.n_layers,
                        handshake=sim.cfg.hw.handshake,
                        link_bw=sim.cfg.hw.link_bw,
                        page_bytes=sim.cost.kv_page_bytes_per_layer())
        rec = None
        if sim.cfg.faults is not None:
            # deliver the plan through the fault plane: retry/backoff +
            # fresh replan of missing groups. TTFT inflation flows
            # naturally through the recovered plan's exposed tail; an
            # unrecoverable loss kills the request (surfaced in
            # lost_requests, never silently dropped).
            try:
                p, rec = sim.cost.recover_transfer(
                    p, sim.injector,
                    sim.retry if sim.cfg.fault_recovery else NO_RETRY,
                    key=req.request_id, replan=sim.cfg.fault_recovery)
                sim.n_transfer_retries += rec.retries
                sim.transfer_retry_time += rec.retry_time
                sim.metrics.counter("recovery_retries_total",
                                    site="transfer").inc(rec.retries)
                sim.metrics.counter("transfer_replans_total").inc(
                    rec.replanned_groups)
                sim.metrics.counter("retry_time_seconds_total",
                                    site="transfer").inc(rec.retry_time)
            except TransferError:
                req.killed = True
                sim.n_lost += 1
                sim.metrics.counter("lost_requests_total").inc()
        emit_spans(sim.tracer, p, base=sim.loop.now,
                   handshake=sim.cfg.hw.handshake,
                   compute_track=self.spec.name,
                   link_track=f"{self.spec.name}->{d_inst.spec.name}",
                   request_id=req.request_id, recovery=rec)
        sim.kv_plans.append(p)
        retry_t = rec.retry_time if rec is not None else 0.0
        # layer-wise blocking handshakes stretch prefill itself
        sim.loop.after(p.prefill_end, lambda: self._finish_prefill(
            req, d_inst, join_delay=max(0.0, p.total_done - p.prefill_end),
            retry_t=retry_t))

    def _finish_prefill(self, req: Request, d_inst: "_Instance",
                        join_delay: float, retry_t: float = 0.0) -> None:
        sim = self.sim

        def emit() -> None:
            if req.killed:
                # lost on the P->D fabric (recovery exhausted or off):
                # account and retire without a first token
                req.t_done = sim.loop.now
                sim.done.append(req)
                sim.acc.close(req.request_id)
                return
            # the exposed transfer tail the request just sat through
            # includes the recovery backoff: reclassify that slice of
            # the transfer component as retry (zero-sum, clamped)
            sim.acc.note(req.request_id, "retry", retry_t,
                         source="transfer")
            # first token gated on the Decode side holding the full KV
            # (kv_transfer's "TTFT gate"): the exposed transfer tail sits
            # on the TTFT critical path, which is what the grouped /
            # chunked streaming schemes shrink
            req.t_first_token = sim.loop.now
            sim.acc.mark_first_token(req.request_id)
            req.output_tokens.append(0)
            if req.max_new_tokens <= 1:
                req.t_done = sim.loop.now
                sim.done.append(req)
                sim.acc.close(req.request_id, len(req.output_tokens))
            else:
                d_inst.join_decode(req)

        if join_delay > 0:
            sim.acc.set_state(req.request_id, "transfer")
            sim.loop.after(join_delay, emit)
        else:
            emit()
        self._next()

    # ---- decode-OOM handling: preempt (swap) or kill ----
    def _pick_victim(self, guarded: bool) -> Optional[int]:
        cands = []
        for rid, (req, _rem) in self.decode_batch.items():
            mark = self._resume_marks.get(rid)
            cands.append(VictimCandidate(
                slot=rid, pages_lost=self._held_pages(req),
                priority=req.priority,
                made_progress=(mark is None
                               or len(req.output_tokens) > mark),
                preempt_count=req.n_preempts if guarded else 0))
        v = pick_preemption_victim(cands)
        return None if v is None else v.slot

    def _preempt(self, rid: int) -> None:
        req, remaining = self.decode_batch.pop(rid)
        self.sim.router.on_decode_leave(self.spec.name)
        req.n_preempts += 1
        self.sim.n_preempted += 1
        self.sim.metrics.counter("preemptions_total",
                                 engine=self.spec.name).inc()
        self._swap_penalty += self.sim.cost.swap_time(self._held_pages(req))
        self.sim.acc.set_state(rid, "queue")
        if self.sim.tracer.enabled:
            self._parked_at[rid] = self.sim.loop.now
        self.preempted.append((req, remaining))

    def _kill(self, rid: int) -> None:
        req, _ = self.decode_batch.pop(rid)
        self.sim.router.on_decode_leave(self.spec.name)
        req.killed = True
        req.t_done = self.sim.loop.now
        self.sim.n_killed += 1
        self.sim.metrics.counter("killed_requests_total").inc()
        self.sim.done.append(req)
        self.sim.acc.close(rid, len(req.output_tokens))

    def _finish_decode_iter(self) -> None:
        sim = self.sim
        finished = []
        for rid, (req, remaining) in list(self.decode_batch.items()):
            req.output_tokens.append(0)
            remaining -= 1
            if remaining <= 0:
                req.t_done = sim.loop.now
                finished.append(rid)
                sim.done.append(req)
                sim.acc.close(rid, len(req.output_tokens))
            else:
                self.decode_batch[rid] = (req, remaining)
        for rid in finished:
            del self.decode_batch[rid]
            self._resume_marks.pop(rid, None)
            sim.router.on_decode_leave(self.spec.name)
        # KV-capacity pressure from this iteration's growth: preempt
        # victims to host (resumable) or kill them (the baseline) —
        # never the last active request (it over-commits instead)
        cap = sim.cfg.decode_kv_pages
        while cap and self._pages_used() > cap and len(self.decode_batch) > 1:
            rid = self._pick_victim(guarded=sim.cfg.preemption)
            if rid is None:
                break                 # all starvation-guarded: over-commit
            if sim.cfg.preemption:
                self._preempt(rid)
            else:
                self._kill(rid)
        # resume preempted requests first (FIFO — they hold progress and
        # already paid for their pages once), then drain the admit queue
        while (self.preempted
               and len(self.decode_batch) < sim.cfg.decode_batch_max):
            req, remaining = self.preempted[0]
            if cap and self._pages_used() + self._held_pages(req) > cap:
                break
            self.preempted.pop(0)
            swap_t = sim.cost.swap_time(self._held_pages(req))
            self._swap_penalty += swap_t
            # the parked wait accrued as queue time; the out+in copies
            # of its pages are really swap traffic — reclassify
            sim.acc.note(req.request_id, "swap", 2 * swap_t,
                         source="queue")
            if sim.tracer.enabled:
                t0 = self._parked_at.pop(req.request_id, sim.loop.now)
                sim.tracer.add("preempt.parked", t0, sim.loop.now,
                               track=self.spec.name,
                               request_id=req.request_id)
            self.decode_batch[req.request_id] = (req, remaining)
            sim.acc.set_state(req.request_id, "compute")
            self._resume_marks[req.request_id] = len(req.output_tokens)
            sim.router.on_decode_join(self.spec.name)
        while self.decode_wait and self._can_admit(self.decode_wait[0]):
            self.join_decode(self.decode_wait.pop(0))
        self._next()


class Simulator:
    def __init__(self, model: ModelConfig, cfg: SimConfig):
        import dataclasses
        from repro.core.deployment import scale
        self.model = model
        if cfg.decode_kv_pages and not cfg.kv_page_tokens:
            # capacity is counted in pages: the page size must be real so
            # held-page math and swap_time agree with the paged layout
            cfg = dataclasses.replace(cfg, kv_page_tokens=16)
        self.cfg = cfg
        dep = parse(cfg.deployment) if isinstance(cfg.deployment, str) \
            else cfg.deployment
        self.deployment = scale(dep, cfg.replicas)
        self.cost = CostModel(model, cfg.hw, page_tokens=cfg.kv_page_tokens)
        self.loop = EventLoop()
        self.router = Router(self.deployment)
        # telemetry plane: the accountant rides the event loop — every
        # simulated-time advance is charged to all open requests under
        # their current stage state, so the per-request components sum
        # to e2e by construction. The tracer (when given) is re-clocked
        # onto simulated time so spans land on the event-loop timeline.
        self.metrics = cfg.metrics if cfg.metrics is not None \
            else MetricsRegistry()
        self.tracer = cfg.tracer if cfg.tracer is not None else NULL_TRACER
        if cfg.tracer is not None:
            cfg.tracer.set_clock(lambda: self.loop.now)
        self.acc = LatencyAccountant()         # simulated time, no wall
        self.loop.on_advance = self.acc.advance
        # one seeded fault plane across the store and transfer domains.
        # With a fault plan configured, recovery defaults to the standard
        # backoff policy; without one, NO_RETRY keeps the legacy
        # single-attempt semantics exactly.
        self.injector = FaultInjector(cfg.faults, metrics=self.metrics)
        if cfg.retry is not None:
            self.retry = cfg.retry
        else:
            self.retry = DEFAULT_RETRY if cfg.faults is not None else NO_RETRY
        self.store = MMStore(injector=self.injector)
        self.prefetcher = EPPrefetcher(self.loop, self.store, self.cost,
                                       async_mode=cfg.ep_async)
        self.instances = {s.name: _Instance(self, s)
                          for s in self.deployment.instances}
        self.done: List[Request] = []
        self.kv_plans: list = []
        self.prefix_hit_tokens = 0.0
        self.prefix_prompt_tokens = 0.0
        self.n_preempted = 0
        self.n_killed = 0
        self.n_lost = 0
        self.n_transfer_retries = 0
        self.transfer_retry_time = 0.0
        if cfg.prefix_cache:
            from repro.serving.prefix_cache import PrefixCache
            page = cfg.kv_page_tokens or 16
            self.router.cache_aware = cfg.cache_aware_routing
            for s in self.deployment.instances:
                if s.serves("P"):
                    self.router.register_prefix_cache(
                        s.name,
                        PrefixCache(page,
                                    max_tokens=cfg.prefix_cache_tokens))

    # ---- routing hooks ----
    def pick_decode_instance(self, req: Request, prefer: str) -> _Instance:
        st = self.router.pick("D", self.loop.now, prefer=prefer)
        return self.instances[st.spec.name]

    def submit(self, req: Request) -> None:
        self.loop.at(req.t_arrival, lambda: self._arrive(req))

    def _arrive(self, req: Request) -> None:
        self.acc.open(req.request_id)
        if req.is_multimodal:
            import hashlib
            key = hashlib.sha256(req.mm_payload).hexdigest()
            if self.store.get(key) is not None:   # counts hit/miss stats
                # cross-request reuse: skip Encode entirely (MM Store hit)
                req.t_encode_start = req.t_encode_done = self.loop.now
                self._to_prefill(req, key)
                return
            st = self.router.pick("E", self.loop.now)
            self.instances[st.spec.name].enqueue("E", req)
        else:
            st = self.router.pick("P", self.loop.now, req=req)
            self.instances[st.spec.name].enqueue("P", req)

    def finish_encode(self, inst: _Instance, req: Request) -> float:
        import hashlib
        key = hashlib.sha256(req.mm_payload).hexdigest()
        self.store.put(key, {"tokens": req.mm_tokens},
                       int(self.cost.feature_bytes(req.mm_tokens)))
        return self._to_prefill(req, key, from_instance=inst)

    def _to_prefill(self, req: Request, key: str,
                    from_instance: Optional[_Instance] = None) -> float:
        st = self.router.pick("P", self.loop.now,
                              prefer=(from_instance.spec.name
                                      if from_instance is not None and
                                      from_instance.spec.serves("P") else None),
                              req=req)
        inst = self.instances[st.spec.name]
        if from_instance is inst:
            inst.enqueue("P", req)           # same instance: no transfer
            return 0.0
        sched_hint = max(0.0, st.busy_until - self.loop.now) \
            + 0.001 * st.pending_tokens
        return self.prefetcher.notify(
            req.request_id, key, req.mm_tokens,
            on_ready=lambda _rec: inst.enqueue("P", req),
            scheduling_latency_hint=sched_hint)

    # ---- run ----
    def run(self, requests: List[Request]) -> SimMetrics:
        for r in requests:
            self.submit(r)
        self.loop.run()
        assert len(self.done) == len(requests), \
            f"stuck: {len(self.done)}/{len(requests)} finished"
        # lost requests never emitted a first token: they are accounted
        # in lost_requests, not in the latency percentiles
        ttfts = sorted(r.ttft * 1e3 for r in self.done
                       if r.t_first_token >= 0) or [0.0]
        tpots = sorted(r.tpot * 1e3 for r in self.done
                       if r.t_first_token >= 0) or [0.0]
        makespan = max(r.t_done for r in self.done) - min(
            r.t_arrival for r in self.done)
        toks = sum(len(r.output_tokens) for r in self.done)
        return SimMetrics(
            deployment=self.deployment.name,
            n_chips=self.deployment.n_chips,
            requests=list(self.done),
            makespan=makespan,
            mean_ttft_ms=sum(ttfts) / len(ttfts),
            p99_ttft_ms=quantile(ttfts, 0.99),
            mean_tpot_ms=sum(tpots) / len(tpots),
            p99_tpot_ms=quantile(tpots, 0.99),
            throughput_tok_s=toks / makespan if makespan > 0 else 0.0,
            store_hit_rate=self.store.stats.hit_rate,
            ep_overlap_ratio=self.prefetcher.mean_overlap_ratio,
            prefix_hit_rate=(self.prefix_hit_tokens / self.prefix_prompt_tokens
                             if self.prefix_prompt_tokens else 0.0),
            completed_requests=sum(not r.killed for r in self.done),
            killed_requests=self.n_killed,
            n_preemptions=self.n_preempted,
            lost_requests=self.n_lost,
            transfer_retries=self.n_transfer_retries,
            retry_time_ms=self.transfer_retry_time * 1e3,
            attribution=self.acc.report(),
            telemetry=self.metrics.snapshot(),
        )


def simulate(model: ModelConfig, deployment: str, dataset: DatasetSpec,
             *, rate: float, n_requests: int = 512, seed: int = 0,
             kv_scheme: str = "grouped", ep_async: bool = True,
             replicas: int = 1, hw: Hardware = V5E,
             per_chip_rate: bool = False,
             kv_page_tokens: int = 0,
             prefix_cache: bool = False,
             cache_aware_routing: bool = True,
             chunked_prefill: bool = False,
             prefill_chunk_tokens: int = 256,
             decode_kv_pages: int = 0,
             preemption: bool = False,
             faults: Optional[FaultPlan] = None,
             retry: Optional[RetryPolicy] = None,
             fault_recovery: bool = True,
             tracer: Optional[Tracer] = None,
             metrics: Optional[MetricsRegistry] = None) -> SimMetrics:
    """Run one deployment against a trace injected at ``rate`` req/s.

    per_chip_rate=True multiplies the rate by the deployment's chip count
    — the paper's figures 8-17 report a per-NPU x-axis so bigger
    deployments absorb proportionally more traffic; Table 5 compares
    deployments at one TOTAL rate (its effective-throughput arithmetic
    only closes under that reading).
    """
    cfg = SimConfig(deployment=deployment, kv_scheme=kv_scheme,
                    ep_async=ep_async, replicas=replicas, hw=hw,
                    kv_page_tokens=kv_page_tokens,
                    prefix_cache=prefix_cache,
                    cache_aware_routing=cache_aware_routing,
                    chunked_prefill=chunked_prefill,
                    prefill_chunk_tokens=prefill_chunk_tokens,
                    decode_kv_pages=decode_kv_pages,
                    preemption=preemption,
                    faults=faults, retry=retry,
                    fault_recovery=fault_recovery,
                    tracer=tracer, metrics=metrics)
    sim = Simulator(model, cfg)
    if per_chip_rate:
        rate = rate * sim.deployment.n_chips
    reqs = gen_requests(dataset, n_requests, rate, seed)
    return sim.run(reqs)

"""Pallas TPU decode attention: one query token vs. a long KV cache.

Flash-decode adapted to the TPU grid model: instead of CUDA-style split-K
across SMs + a second reduction kernel, the kv-block dim is the innermost
(sequential) grid dimension and the running (m, l, acc) lives in VMEM
scratch — the TensorCore streams KV blocks HBM->VMEM while the per-block
math stays on the VPU/MXU. All q-heads of one kv group are processed
together so the (group x block_k) score tile is 2D (MXU/VPU friendly)
even though there is a single query token.

This is the Decode-stage hot loop of the paper's disaggregated serving
system (memory-bound, arithmetic intensity ~= group size).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, window: Optional[int],
            nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (g, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qpos_ref[0, 0]                            # scalar
    kpos = kpos_ref[0]                               # (bk,)
    valid = (kpos >= 0) & (kpos <= qpos)
    if window is not None:
        valid &= kpos > qpos - window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid[None, :], p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _done():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, q_pos, kv_pos, *, window: Optional[int] = None,
                     block_k: int = 512, interpret: bool = False):
    """q: (b, nq, hd); k, v: (b, S, nkv, hd); q_pos: (b,); kv_pos: (b, S)."""
    b, nq, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    block_k = min(block_k, S)

    r = (-S) % block_k
    kt = jnp.moveaxis(k, 2, 1)                        # (b, nkv, S, hd)
    vt = jnp.moveaxis(v, 2, 1)
    kp = kv_pos
    if r:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, r), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, r), (0, 0)))
        kp = jnp.pad(kv_pos, ((0, 0), (0, r)), constant_values=-1)
    nk = kt.shape[2] // block_k

    qg = q.reshape(b, nkv, g, hd)
    qp2 = q_pos[:, None].astype(jnp.int32)            # (b, 1)

    grid = (b, nkv, nk)
    kern = functools.partial(_kernel, scale=hd ** -0.5, window=window, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, h, j: (bi, 0)),
            pl.BlockSpec((1, block_k), lambda bi, h, j: (bi, j)),
            pl.BlockSpec((1, 1, g, hd), lambda bi, h, j: (bi, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, h, j: (bi, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, h, j: (bi, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, h, j: (bi, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        interpret=interpret,
    )(qp2, kp, qg, kt, vt)
    return out.reshape(b, nq, hd)

"""Checkpoint save/restore round-trips + safety checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import init_params
from repro.training.checkpoint import (latest_step, restore_checkpoint,
                                       save_checkpoint)
from repro.training.data import synthetic_batches
from repro.training.optimizer import AdamW
from repro.training.train import make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3, warmup_steps=1)
    return cfg, params, opt


def test_roundtrip_exact(tmp_path, setup):
    cfg, params, opt = setup
    state = opt.init(params)
    save_checkpoint(tmp_path, cfg, params, state, step=7)
    p2, s2, step = restore_checkpoint(tmp_path, cfg, params, state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(state.mu), jax.tree.leaves(s2.mu)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_training_resumes_identically(tmp_path, setup):
    """train 2 steps == train 1, checkpoint, restore, train 1."""
    cfg, params, opt = setup
    step_fn = jax.jit(make_train_step(cfg, opt, remat=False))
    batches = list(synthetic_batches(cfg, 4, 16, 2, seed=9))

    pA, sA = params, opt.init(params)
    for b in batches:
        pA, sA, _ = step_fn(pA, sA, b)

    pB, sB = params, opt.init(params)
    pB, sB, _ = step_fn(pB, sB, batches[0])
    save_checkpoint(tmp_path, cfg, pB, sB, step=1)
    pB, sB, _ = restore_checkpoint(tmp_path, cfg, pB, sB)
    pB, sB, _ = step_fn(pB, sB, batches[1])

    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-5)


def test_retention(tmp_path, setup):
    cfg, params, opt = setup
    for s in range(5):
        save_checkpoint(tmp_path, cfg, params, None, step=s, keep=2)
    assert latest_step(tmp_path) == 4
    import pathlib
    kept = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert kept == ["step_00000003", "step_00000004"]


def test_wrong_arch_rejected(tmp_path, setup):
    cfg, params, opt = setup
    save_checkpoint(tmp_path, cfg, params, None, step=0)
    other = get_config("llama3.2-1b").reduced()
    with pytest.raises(ValueError, match="arch"):
        restore_checkpoint(tmp_path, other, params)

"""Kernel / engine microbenchmarks (CPU-executable path).

Times the jnp reference implementations (the CPU stand-ins for the Pallas
kernels — the kernels themselves only run for real on TPU; interpret mode
timing is meaningless) and the end-to-end engine steps on reduced configs.
Rows: name,us_per_call,derived.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_kernels() -> List[str]:
    from repro.kernels.decode_attention.ref import decode_attention_ref
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.ssd_scan.ref import ssd_ref

    rows = ["kernel,us_per_call,derived"]
    key = jax.random.PRNGKey(0)

    b, s, nq, nkv, hd = 2, 512, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, nq, hd))
    k = jax.random.normal(ks[1], (b, s, nkv, hd))
    v = jax.random.normal(ks[2], (b, s, nkv, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    fa = jax.jit(lambda *a: attention_ref(*a))
    us = _time(fa, q, k, v, pos, pos)
    flops = 4 * b * nq * s * (s / 2) * hd
    rows.append(f"flash_attention_ref_b{b}_s{s},{us:.0f},"
                f"{flops / us / 1e3:.1f}_gflops")

    S = 4096
    kd = jax.random.normal(ks[1], (b, S, nkv, hd))
    vd = jax.random.normal(ks[2], (b, S, nkv, hd))
    kp = jnp.broadcast_to(jnp.arange(S), (b, S))
    qd = jax.random.normal(ks[0], (b, nq, hd))
    qp = jnp.array([S - 1] * b)
    da = jax.jit(lambda *a: decode_attention_ref(*a))
    us = _time(da, qd, kd, vd, qp, kp)
    kv_bytes = b * S * nkv * hd * 2 * 4
    rows.append(f"decode_attention_ref_b{b}_S{S},{us:.0f},"
                f"{kv_bytes / us / 1e3:.1f}_GBps_kvread")

    B, L, H, P, N, chunk = 2, 1024, 4, 64, 64, 128
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    bm = jax.random.normal(ks[3], (B, L, N)) * 0.3
    cm = jax.random.normal(ks[4], (B, L, N)) * 0.3
    dsk = jnp.ones((H,))
    sf = jax.jit(lambda *args: ssd_ref(*args, chunk))
    us = _time(sf, x, dt, a, bm, cm, dsk)
    rows.append(f"ssd_scan_ref_B{B}_L{L},{us:.0f},"
                f"{B * L / us:.2f}_tokens_per_us")
    return rows


def bench_paged_kv() -> List[str]:
    """Paged-vs-dense decode attention + per-insert bytes moved.

    Decode: dense streams all max_len KV positions per step; paged
    gathers only the pages of the ACTUAL length through the block table.
    Insert: dense copies a whole (layers, max_len, ...) slot row; paged
    moves ceil(prompt/page) pages. Emits a BENCH_paged_kv.json snapshot
    next to the repo root so the perf trajectory is recorded per PR.
    """
    import json
    import os

    import numpy as np

    from repro.kernels.decode_attention.ref import decode_attention_ref
    from repro.kernels.paged_decode_attention.ref import (
        paged_decode_attention_ref)

    rows = ["paged_kv,us_per_call,derived"]
    key = jax.random.PRNGKey(0)
    snap = {}

    # ---- decode attention: max_len stream vs actual-length pages ----
    b, nq, nkv, hd = 4, 8, 2, 64
    max_len, actual, page = 4096, 128, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, nq, hd))
    kd = jax.random.normal(ks[1], (b, max_len, nkv, hd))
    vd = jax.random.normal(ks[2], (b, max_len, nkv, hd))
    kp_pos = jnp.broadcast_to(jnp.arange(max_len), (b, max_len))
    qp = jnp.array([actual - 1] * b)
    dense_fn = jax.jit(lambda *a: decode_attention_ref(*a))
    us_dense = _time(dense_fn, q, kd, vd, qp, kp_pos)

    n_pages = b * (actual // page) + 1
    k_pool = jax.random.normal(ks[1], (n_pages, page, nkv, hd))
    v_pool = jax.random.normal(ks[2], (n_pages, page, nkv, hd))
    tbl = jnp.asarray(
        1 + np.arange(b * (actual // page)).reshape(b, -1), jnp.int32)
    lens = jnp.array([actual] * b, jnp.int32)
    paged_fn = jax.jit(lambda *a: paged_decode_attention_ref(*a))
    us_paged = _time(paged_fn, q, k_pool, v_pool, tbl, lens)
    rows.append(f"decode_dense_ref_S{max_len},{us_dense:.0f},"
                f"streams_{max_len}_kv")
    rows.append(f"decode_paged_ref_len{actual},{us_paged:.0f},"
                f"{us_dense / max(us_paged, 1e-9):.1f}x_vs_dense")
    snap["decode_dense_us"] = round(us_dense, 1)
    snap["decode_paged_us"] = round(us_paged, 1)
    snap["decode_speedup"] = round(us_dense / max(us_paged, 1e-9), 2)

    # ---- per-insert KV bytes moved (the P->D handoff payload) ----
    from repro.configs import get_config
    from repro.models.model import init_params
    from repro.serving.engine import Engine
    from repro.serving.request import Request

    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = list(range(2, 10))               # prompt=8 (acceptance shape)
    dense_eng = Engine(cfg, params, max_batch=4, max_len=128)
    r = Request(prompt_tokens=list(prompt), max_new_tokens=2)
    first, payload = dense_eng.prefill_request(r)
    dense_eng.insert(r, payload, first)

    paged_src = Engine(cfg, params, max_batch=1, max_len=128, paged=True,
                       page_size=16)
    paged_dst = Engine(cfg, params, max_batch=4, max_len=128, paged=True,
                       page_size=16)
    r2 = Request(prompt_tokens=list(prompt), max_new_tokens=2)
    first2, payload2 = paged_src.prefill_request(r2)
    paged_dst.insert(r2, payload2, first2)    # cross-engine page copy
    ratio = dense_eng.kv_insert_bytes / max(paged_dst.kv_insert_bytes, 1)
    rows.append(f"insert_bytes_dense_b4_len128_p8,"
                f"{dense_eng.kv_insert_bytes},bytes_per_insert")
    rows.append(f"insert_bytes_paged_b4_len128_p8,"
                f"{paged_dst.kv_insert_bytes},{ratio:.1f}x_reduction")
    r3 = Request(prompt_tokens=list(prompt), max_new_tokens=2)
    first3, payload3 = paged_dst.prefill_request(r3)
    paged_dst.insert(r3, payload3, first3)    # fused: zero-copy handoff
    rows.append(f"insert_bytes_paged_fused,"
                f"{paged_dst.kv_insert_bytes},block_table_handoff_only")
    snap["insert_bytes_dense"] = int(dense_eng.kv_insert_bytes)
    snap["insert_bytes_paged"] = int(payload2.kv_nbytes)
    snap["insert_bytes_fused"] = int(paged_dst.kv_insert_bytes)
    snap["insert_bytes_ratio"] = round(ratio, 2)
    snap["config"] = dict(model="smollm-135m.reduced", max_batch=4,
                          max_len=128, prompt=8, page_size=16)

    out_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_paged_kv.json")
    with open(out_path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    rows.append(f"# snapshot -> {out_path}")
    return rows


def bench_engine() -> List[str]:
    from repro.configs import get_config
    from repro.models.model import init_params
    from repro.serving.engine import Engine
    from repro.serving.request import Request

    rows = ["engine,us_per_call,derived"]
    cfg = get_config("smollm-135m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=4, max_len=64)
    reqs = [Request(prompt_tokens=list(range(2, 10)), max_new_tokens=50)
            for _ in range(4)]
    t0 = time.perf_counter()
    for r in reqs:
        first, caches = eng.prefill_request(r)
        eng.insert(r, caches, first)
    t_pre = (time.perf_counter() - t0) / len(reqs) * 1e6
    rows.append(f"engine_prefill_insert,{t_pre:.0f},batch1_len8")
    n = 0
    t0 = time.perf_counter()
    while eng.n_active:
        eng.decode_step()
        n += 1
    t_dec = (time.perf_counter() - t0) / max(n, 1) * 1e6
    rows.append(f"engine_decode_step,{t_dec:.0f},batch4_{n}_iters")
    return rows

"""Training step + loop (substrate for the train_4k input shape)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import train_forward
from repro.training.optimizer import AdamW, AdamWState


def make_train_step(cfg: ModelConfig, opt: AdamW, remat: bool = True,
                    num_microbatches: int = 1, loss_chunk: int = 0,
                    grad_specs=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). jit/pjit-able; used both for real CPU training and for the
    dry-run lowering at full scale.

    num_microbatches > 1 enables gradient accumulation (scan over
    microbatches) — required at global_batch=256 x 4k so the per-micro
    vocab logits stay within per-chip HBM.
    grad_specs: optional PartitionSpec pytree matching params — constrains
    the gradient accumulator so XLA reduce-scatters per-micro grads onto
    the ZeRO shards instead of all-reducing full-size gradients.
    """
    def _constrain(tree):
        if grad_specs is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_specs)

    def loss_fn(params, batch):
        total, metrics = train_forward(params, cfg, batch, remat=remat,
                                       loss_chunk=loss_chunk)
        return total, metrics

    def train_step(params, opt_state: AdamWState, batch: Dict[str, Any]):
        if num_microbatches <= 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            n = num_microbatches
            micro = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                batch)

            def acc(carry, mb):
                g_sum, l_sum, a_sum = carry
                (_, m), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g = _constrain(g)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_sum, g)
                return (_constrain(g_sum), l_sum + m["loss"],
                        a_sum + m["aux"]), None

            zeros = _constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (g_sum, l_sum, a_sum), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros(()), jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / n, g_sum)
            metrics = {"loss": l_sum / n, "aux": a_sum / n}
        new_params, new_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        return new_params, new_state, metrics

    return train_step


def train_loop(cfg: ModelConfig, params, batches, *, opt: Optional[AdamW] = None,
               remat: bool = False):
    """Simple CPU-scale training loop over an iterable of batches."""
    opt = opt or AdamW()
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, remat=remat))
    losses = []
    for batch in batches:
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    return params, opt_state, losses

"""Radix-tree prefix cache: tree semantics, ref-count invariants
(hypothesis), engine cold-vs-warm token parity (boundary / CoW /
no-match), LRU eviction under pool pressure, and cache-aware routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.kv_pool import PagePool
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request


# ---------------------------------------------------------------------------
# page pool: ref counts
# ---------------------------------------------------------------------------

def test_pool_refcount_lifecycle():
    pool = PagePool(9, page_size=8)
    assert len(pool.alloc(0)) == 0               # no-op, not a drain
    assert pool.n_free == 8
    ids = pool.alloc(3)
    pool.ref(ids)                                 # second holder
    pool.free(ids)
    assert pool.n_used == 3                       # still held once
    pool.free(ids)
    assert pool.n_used == 0
    with pytest.raises(ValueError, match="double free"):
        pool.free([int(ids[0])])
    with pytest.raises(ValueError, match="unallocated"):
        pool.ref([int(ids[0])])


def test_pool_free_list_lifo_order_kept():
    pool = PagePool(6, page_size=4)
    a = pool.alloc(2)
    pool.free(a)
    b = pool.alloc(2)
    # LIFO: most recently freed page comes back first
    assert list(b) == list(a)[::-1]


def test_pool_assert_balanced_catches_leak():
    pool = PagePool(9, page_size=8)
    ids = pool.alloc(2)
    pool.assert_balanced([ids])                  # accounted: passes
    with pytest.raises(AssertionError, match="leaked"):
        pool.assert_balanced([])
    pool.ref([int(ids[0])])
    with pytest.raises(AssertionError, match="refs but"):
        pool.assert_balanced([ids])              # one page has 2 refs
    pool.assert_balanced([ids, [int(ids[0])]])


# ---------------------------------------------------------------------------
# radix tree (pool-less: pure token matching, the simulator/router mode)
# ---------------------------------------------------------------------------

def test_tree_match_grows_with_inserts():
    c = PrefixCache(page_size=4)
    assert c.match_len([1, 2, 3, 4, 5]) == 0
    c.insert([1, 2, 3, 4, 5, 6, 7, 8])           # 2 full pages
    assert c.match_len([1, 2, 3, 4, 5, 6, 7, 8, 9]) == 8
    assert c.match_len([1, 2, 3, 4, 9, 9]) == 4       # page boundary
    assert c.match_len([1, 2, 3, 4, 5, 6, 9]) == 6    # intra-page partial
    assert c.match_len([9, 1, 2, 3]) == 0


def test_tree_partial_page_never_cached():
    c = PrefixCache(page_size=4)
    c.insert([1, 2, 3, 4, 5, 6])                 # 1.5 pages -> 1 page kept
    assert c.match_len([1, 2, 3, 4, 5, 6, 7]) == 4
    assert c.n_cached_tokens == 4


def test_tree_split_preserves_sibling_branches():
    c = PrefixCache(page_size=2)
    c.insert([1, 2, 3, 4, 5, 6])
    c.insert([1, 2, 3, 4, 9, 9])                 # splits at page boundary
    c.insert([1, 2, 7, 7])
    assert c.match_len([1, 2, 3, 4, 5, 6]) == 6
    assert c.match_len([1, 2, 3, 4, 9, 9]) == 6
    assert c.match_len([1, 2, 7, 7]) == 4
    assert c.match_len([1, 2, 8, 8]) == 2


def test_tree_cap_forces_partial_match():
    c = PrefixCache(page_size=4)
    c.insert(list(range(8)))
    m = c.match_and_ref(list(range(8)), cap=7)
    assert m.n_tokens == 7                       # cap: never the full prompt
    assert m.n_full_pages == 0                   # pool-less: no page ids


# ---------------------------------------------------------------------------
# radix tree over a real pool: refs, CoW source, eviction
# ---------------------------------------------------------------------------

def _insert_seq(cache, pool, tokens):
    """Simulate a request retaining its prefill pages in the tree."""
    ids = pool.alloc(pool.pages_for(len(tokens)))
    cache.insert(tokens, ids)
    return ids


def test_tree_refs_and_cow_source():
    pool = PagePool(32, page_size=4)
    c = PrefixCache(4, pool)
    ids = _insert_seq(c, pool, list(range(8)))   # req holds 1 ref, tree 1
    for p in ids:
        assert pool.refcount(p) == 2
    m = c.match_and_ref([0, 1, 2, 3, 4, 9, 9, 9])
    assert m.n_tokens == 5
    assert list(m.page_ids) == [int(ids[0])]
    assert m.cow_src == int(ids[1])
    assert pool.refcount(ids[0]) == 3            # req + tree + match
    assert pool.refcount(ids[1]) == 3            # .. + cow ref
    pool.unref(m.page_ids)
    pool.unref([m.cow_src])
    pool.assert_balanced([ids, c.retained_pages()])


def test_tree_eviction_frees_lru_only_and_skips_in_use():
    pool = PagePool(9, page_size=4)              # 8 usable pages
    c = PrefixCache(4, pool)
    a = _insert_seq(c, pool, [1] * 8)            # 2 pages
    b = _insert_seq(c, pool, [2] * 8)            # 2 pages
    pool.free(a)                                 # request a done: tree-only
    m = c.match_and_ref([2] * 8)                 # touch b (MRU) + ref
    pool.free(m.page_ids)                        # drop the match refs
    freed = c.evict(1)
    assert freed == 2                            # whole LRU leaf 'a' dropped
    assert c.match_len([1] * 8) == 0
    assert c.match_len([2] * 8) == 8             # unrelated branch intact
    # b's pages are still held by their request: nothing freeable remains,
    # so eviction must not drop that retention
    assert c.evict(10) == 0
    assert c.match_len([2] * 8) == 8
    pool.free(b)                                 # request b releases
    assert c.evict(10) == 2                      # now the tree lets go
    pool.assert_balanced([])


def test_tree_eviction_reclaims_parent_after_leaf():
    pool = PagePool(17, page_size=2)
    c = PrefixCache(2, pool)
    x = _insert_seq(c, pool, [1, 2, 3, 4])       # 2 pages, both retained
    y = _insert_seq(c, pool, [1, 2, 9, 9])       # splits; retains y's page 1
    pool.free(x)
    pool.free(y)                                 # y page 0 freed here (never
    #                                              retained: run was cached)
    assert c.evict(100) == 3                     # x1 + y1 leaves, then x0
    assert c.match_len([1, 2]) == 0
    pool.assert_balanced([])


# ---------------------------------------------------------------------------
# engine: cold-vs-warm parity + CoW KV byte equality
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smollm():
    from repro.models.model import init_params
    cfg = get_config("smollm-135m").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _fresh(cfg, params, prefix=False, **kw):
    from repro.serving.engine import Engine
    return Engine(cfg, params, max_batch=2, max_len=64, paged=True,
                  page_size=8, prefix_cache=prefix, **kw)


def _serve(eng, prompt, n=6):
    r = Request(prompt_tokens=list(prompt), max_new_tokens=n)
    f, p = eng.prefill_request(r)
    eng.insert(r, p, f)
    while any(s is r for s in eng.slots):
        eng.decode_step()
    return r.output_tokens


BASE = list(range(2, 22))                        # 20 tokens = 2.5 pages @8


def test_warm_matches_cold_tokens(smollm):
    """Acceptance: greedy outputs are token-for-token identical whether
    the prefix came from the cache or was computed cold, for a match at a
    page boundary, a match inside a page (CoW), a miss, an extension of a
    cached prompt, and an identical re-run (capped at len-1)."""
    cfg, params = smollm
    cold = _fresh(cfg, params)
    warm = _fresh(cfg, params, prefix=True, n_pool_pages=64)
    assert _serve(cold, BASE) == _serve(warm, BASE)      # seed the cache
    probes = (BASE[:16] + [55, 56],              # match ends on page edge
              BASE[:10] + [99, 98, 97],          # diverges inside page 2: CoW
              [77, 78, 79, 80],                  # no match at all
              BASE + [30, 31, 32],               # extends cached prompt
              list(BASE))                        # full re-run (cap len-1)
    for probe in probes:
        computed_before = warm.prefill_tokens_computed
        assert _serve(cold, probe) == _serve(warm, probe), probe
        hit = warm.prefill_tokens_computed - computed_before < len(probe)
        assert hit == (probe[0] == BASE[0])      # every BASE probe hits
        warm.assert_no_page_leaks()
        cold.assert_no_page_leaks()


def test_cow_kv_matches_cold_prefill_bytes(smollm):
    """The CoW page + recomputed suffix hold the same KV a cold prefill
    produces: gather both engines' pools through their block tables and
    compare the request's valid tokens."""
    cfg, params = smollm
    cold = _fresh(cfg, params)
    warm = _fresh(cfg, params, prefix=True, n_pool_pages=64)
    _serve(warm, BASE, n=1)
    probe = BASE[:10] + [99, 98, 97]             # CoW inside page 2
    rc = Request(prompt_tokens=probe, max_new_tokens=1)
    rw = Request(prompt_tokens=probe, max_new_tokens=1)
    fc, pc = cold.prefill_request(rc)
    fw, pw = warm.prefill_request(rw)
    assert pw.cached_tokens > 0 and pw.cached_tokens % warm.page_size != 0
    assert fc == fw
    n = pc.n_tokens
    for ec, ew in zip(cold.caches["attn"], warm.caches["attn"]):
        if ec is None:
            continue
        for arr_c, arr_w, src_c, src_w in ((ec.k, ew.k, pc, pw),
                                           (ec.v, ew.v, pc, pw)):
            kv_c = np.asarray(arr_c[:, src_c.page_ids]).reshape(
                arr_c.shape[0], -1, *arr_c.shape[3:])[:, :n]
            kv_w = np.asarray(arr_w[:, src_w.page_ids]).reshape(
                arr_w.shape[0], -1, *arr_w.shape[3:])[:, :n]
            np.testing.assert_allclose(kv_c, kv_w, atol=1e-5, rtol=1e-5)
    cold.release_payload(pc)
    warm.release_payload(pw)
    cold.assert_no_page_leaks()
    warm.assert_no_page_leaks()


def test_engine_eviction_under_pool_pressure(smollm):
    """Distinct prompts overflow a small pool: the engine evicts LRU tree
    retentions instead of failing, and live requests' pages survive."""
    cfg, params = smollm
    eng = _fresh(cfg, params, prefix=True, n_pool_pages=9)   # 8 usable
    outs = {}
    for wave in range(4):                        # 4 distinct 20-tok prompts
        prompt = [100 * wave + j for j in range(20)]
        outs[wave] = _serve(eng, prompt, n=4)
        eng.assert_no_page_leaks()
    assert eng.prefix_cache.stats.evicted_pages > 0
    # re-serving the first prompt (likely evicted) still works + matches
    assert _serve(eng, [0 + j for j in range(20)], n=4) == outs[0]
    eng.assert_no_page_leaks()


def test_engine_early_eos_and_payload_release_paths(smollm):
    """Early-EOS slot release and abandoned payloads leave no leaks."""
    cfg, params = smollm
    eng = _fresh(cfg, params, prefix=True, n_pool_pages=64)
    out = _serve(eng, BASE, n=6)
    eos = out[1]                                 # stop as soon as it appears
    r = Request(prompt_tokens=list(BASE), max_new_tokens=20, eos_token=eos)
    f, p = eng.prefill_request(r)
    eng.insert(r, p, f)
    steps = 0
    while any(s is r for s in eng.slots):
        eng.decode_step()
        steps += 1
    assert steps < 20                            # actually stopped early
    eng.assert_no_page_leaks()
    # payload abandoned before insert: release returns the refs
    r2 = Request(prompt_tokens=BASE[:8] + [5, 5], max_new_tokens=2)
    _, p2 = eng.prefill_request(r2)
    eng.release_payload(p2)
    eng.assert_no_page_leaks()
    # double release stays a no-op
    eng.release_payload(p2)
    eng.assert_no_page_leaks()


def test_failed_suffix_prefill_unwinds_all_refs(smollm, monkeypatch):
    """A device error mid-suffix-prefill must release the match refs, the
    CoW ref, and the fresh pages — retries must not shrink the pool."""
    cfg, params = smollm
    eng = _fresh(cfg, params, prefix=True, n_pool_pages=64)
    _serve(eng, BASE, n=1)
    used = eng.pool.n_used

    def boom(*a, **k):
        raise RuntimeError("injected device OOM")

    monkeypatch.setattr(eng, "_prefill_suffix", boom)
    probe = BASE[:10] + [99, 98, 97]             # CoW path (max refs held)
    with pytest.raises(RuntimeError, match="injected"):
        eng.prefill_request(Request(prompt_tokens=probe, max_new_tokens=1))
    assert eng.pool.n_used == used
    eng.assert_no_page_leaks()
    monkeypatch.undo()
    _serve(eng, probe, n=1)                      # retry succeeds cleanly
    eng.assert_no_page_leaks()


def test_poolless_tree_capacity_is_bounded():
    c = PrefixCache(page_size=4, max_tokens=16)
    for i in range(20):
        c.insert([1000 * i + j for j in range(8)])   # unique 2-page prompts
        assert c.n_cached_tokens <= 16
    # newest entries survive, oldest were LRU-evicted
    assert c.match_len([1000 * 19 + j for j in range(8)]) == 8
    assert c.match_len([0, 1, 2, 3]) == 0


def test_cluster_prefix_cache_end_to_end(smollm):
    """Disaggregated P->D with the prefix cache on the Prefill engine:
    same tokens as without it, fewer prefill tokens computed, and the
    transfer planner charges suffix-only compute overlap."""
    from repro.core.cluster import EPDCluster
    cfg, params = smollm

    def run(prefix):
        cl = EPDCluster(cfg, params, max_batch=2, max_len=64, paged=True,
                        page_size=8, prefix_cache=prefix,
                        n_prefill_pool_pages=33)
        reqs = [Request(prompt_tokens=BASE + [900 + i], max_new_tokens=4)
                for i in range(3)]
        for r in reqs:
            cl.submit(r)
        cl.run_until_done()
        return cl, [r.output_tokens for r in reqs]

    base, outs_b = run(False)
    pfx, outs_p = run(True)
    assert outs_b == outs_p
    peng = pfx.prefill_engine
    assert peng.prefill_tokens_computed < peng.prefill_tokens_total
    assert peng.prefill_tokens_computed < \
        base.prefill_engine.prefill_tokens_computed
    # prefill pool retains only the tree after drain; decode pool empties
    peng.assert_no_page_leaks()
    pfx.decode_engine.assert_no_page_leaks()
    assert peng.pool.n_used == len(peng.prefix_cache.retained_pages())
    assert pfx.decode_engine.pool.n_used == 0


def test_prefix_cache_requires_paged_and_attention_only(smollm):
    from repro.serving.engine import Engine
    cfg, params = smollm
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, params, prefix_cache=True)
    mamba = get_config("mamba2-370m").reduced()
    with pytest.raises(ValueError, match="attention-only"):
        Engine(mamba, None, paged=True, prefix_cache=True,
               max_len=64, page_size=16)


# ---------------------------------------------------------------------------
# cache-aware routing (router unit + 2-Prefill simulator scenario)
# ---------------------------------------------------------------------------

def test_router_prefers_instance_with_longest_prefix():
    from repro.core.deployment import parse
    from repro.core.scheduler import Router
    dep = parse("E-P-P-D")
    router = Router(dep)
    p_names = [i.name for i in dep.stage_instances("P")]
    caches = {n: PrefixCache(4) for n in p_names}
    for n, c in caches.items():
        router.register_prefix_cache(n, c)
    caches[p_names[1]].insert([1, 2, 3, 4, 5, 6, 7, 8])
    # load slightly favours p0, cache credit (8 tokens) outweighs it
    router.status[p_names[0]].busy_until = 0.0
    router.status[p_names[1]].busy_until = 0.004
    req = Request(prompt_tokens=[1, 2, 3, 4, 5, 6, 7, 8, 9])
    assert router.pick("P", 0.0, req=req).spec.name == p_names[1]
    # ...but a deep backlog spills to the idle replica (no pinning)
    router.status[p_names[1]].busy_until = 5.0
    assert router.pick("P", 0.0, req=req).spec.name == p_names[0]
    router.status[p_names[1]].busy_until = 0.004
    # no cached prefix anywhere -> least-loaded fallback
    miss = Request(prompt_tokens=[9, 9, 9, 9])
    assert router.pick("P", 0.0, req=miss).spec.name == p_names[0]
    # ablation flag restores least-loaded-only
    router.cache_aware = False
    assert router.pick("P", 0.0, req=req).spec.name == p_names[0]
    # multimodal requests never consult the token-keyed cache
    router.cache_aware = True
    mm = Request(prompt_tokens=[1, 2, 3, 4, 5, 6, 7, 8, 9],
                 mm_payload=b"img", mm_tokens=4)
    assert router.pick("P", 0.0, req=mm).spec.name == p_names[0]


def test_simulator_cache_aware_routing_raises_hit_rate():
    """Acceptance: with 2 Prefill instances and a shared-prefix workload,
    cache-aware dispatch beats least-loaded-only on aggregate hit rate
    (least-loaded sprays each prefix group across both instances)."""
    import dataclasses
    from repro.core.simulator import SHAREGPT_4O, simulate
    model = get_config("openpangu-7b-vl")
    # long shared prefixes (compute-bound prefill) at moderate load:
    # least-loaded sprays each group across both P instances (2 cold
    # misses per group + random re-spills) while cache-aware dispatch
    # keeps a group with the instance that cached it — unless that
    # instance's backlog outweighs the cached-token credit (no pinning)
    ds = dataclasses.replace(SHAREGPT_4O, mm_fraction=0.0,
                             prefix_groups=32, prefix_tokens=384,
                             text_tokens_mean=16.0)
    kw = dict(rate=20.0, n_requests=128, seed=11, kv_page_tokens=16,
              prefix_cache=True)
    aware = simulate(model, "E-P-P-D", ds, **kw)
    blind = simulate(model, "E-P-P-D", ds, cache_aware_routing=False, **kw)
    assert aware.prefix_hit_rate > blind.prefix_hit_rate + 0.05
    assert aware.prefix_hit_rate > 0.5
    # cached prefixes skip real compute here -> TTFT strictly improves
    assert aware.mean_ttft_ms < blind.mean_ttft_ms


def test_simulator_prefix_cache_off_is_noop():
    from repro.core.simulator import SHAREGPT_4O, simulate
    model = get_config("openpangu-7b-vl")
    m = simulate(model, "E-P-D", SHAREGPT_4O, rate=4.0, n_requests=32,
                 seed=3)
    assert m.prefix_hit_rate == 0.0

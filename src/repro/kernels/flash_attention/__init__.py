from repro.kernels.flash_attention.ops import attention_ref, flash_attention

__all__ = ["flash_attention", "attention_ref"]

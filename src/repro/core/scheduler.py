"""Modality-aware multi-path scheduling + instance-level load balancing
(paper §3.4), with cache-aware Prefill dispatch.

The Router keeps a global instance status table (queue length, pending
work, busy-until estimates) updated by the simulator / engines, routes
multimodal requests down the E->P->D path and text-only requests down the
P->D path, and dispatches each stage task to the least-loaded instance
serving that stage.

Prefill dispatch is additionally *cache-aware* when Prefill instances
register their prefix caches (``register_prefix_cache``): a cached
prefix is credited against an instance's load at the same per-token
weight as pending prefill work, so a text-only request prefers the
instance holding the longest matching prefix — keeping same-prefix
requests together compounds the hit rate instead of spraying a hot
system prompt across every replica — but a deep backlog still spills
the request to an idle replica rather than pinning one instance.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.deployment import Deployment, InstanceSpec
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request


# load-metric weight of one queued prompt token; cached-prefix tokens
# are credited at the same weight in cache-aware dispatch
PENDING_TOKEN_WEIGHT = 1e-3


# ---------------------------------------------------------------------------
# Page-level preemption: victim selection
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class VictimCandidate:
    """One active decode request considered for page-level preemption.

    slot          — engine slot index (or request id in the simulator);
                    the deterministic tiebreak.
    pages_lost    — device pages released if this request is preempted:
                    the private pages that must be swapped to host and
                    re-faulted later (tree-shared pages cost nothing —
                    they are merely unref'd). This is the preemption
                    COST, not the reclaim estimate.
    priority      — the request's priority (higher survives longer).
    made_progress — has it produced at least one token since its last
                    resume (always True for a never-preempted request)?
    preempt_count — how many times it has been preempted already.
    """

    slot: int
    pages_lost: int
    priority: int = 0
    made_progress: bool = True
    preempt_count: int = 0


def pick_preemption_victim(cands: Sequence[VictimCandidate]
                           ) -> Optional[VictimCandidate]:
    """Choose which active request to preempt when a page allocation
    cannot be satisfied (engine decode growth / admission, simulator
    decode capacity).

    Policy: lowest request priority first, then fewest-pages-lost-first
    (the victim whose eviction costs the least swap traffic and
    re-fault work), slot index as the deterministic tiebreak.

    Starvation guard: a request that was already preempted and has not
    produced a single token since its last resume is exempt — preempting
    it again would undo a resume that never ran (swap ping-pong), and
    under sustained pressure it would never finish. Returns None when no
    candidate is eligible; the caller must then deny the allocation
    (raise/queue) instead of thrashing."""
    eligible = [c for c in cands
                if c.made_progress or c.preempt_count == 0]
    if not eligible:
        return None
    return min(eligible, key=lambda c: (c.priority, c.pages_lost, c.slot))


@dataclass
class InstanceStatus:
    spec: InstanceSpec
    queue_len: int = 0             # tasks waiting (all stages)
    active_decode: int = 0         # requests in the decode batch
    pending_tokens: float = 0.0    # queued prompt tokens (work estimate)
    busy_until: float = 0.0        # latest known completion estimate
    down: bool = False             # instance crashed; never dispatch to it
    # per-request pending ledger: rid -> tokens still outstanding. Guards
    # the aggregate against double-retirement when both on_start and
    # chunk-granular on_prefill_progress report the same work.
    pending_by_req: Dict[str, float] = field(default_factory=dict)

    def load(self, now: float) -> float:
        """Scalar load metric for least-loaded-first dispatch."""
        backlog = max(0.0, self.busy_until - now)
        return (backlog + PENDING_TOKEN_WEIGHT * self.pending_tokens
                + 0.01 * self.queue_len + 0.002 * self.active_decode)


class Router:
    def __init__(self, deployment: Deployment):
        self.deployment = deployment
        self.status: Dict[str, InstanceStatus] = {
            i.name: InstanceStatus(i) for i in deployment.instances}
        self.prefix_caches: Dict[str, PrefixCache] = {}
        # cache-aware Prefill dispatch; False = pure least-loaded (the
        # ablation baseline — prefix caches still populate and count hits)
        self.cache_aware = True

    # -- multi-path routing ----------------------------------------------------
    def path(self, req: Request) -> List[str]:
        """Stage path for a request: E->P->D for multimodal, P->D for text."""
        return ["E", "P", "D"] if req.is_multimodal else ["P", "D"]

    def register_prefix_cache(self, name: str, cache: PrefixCache) -> None:
        """Make instance ``name``'s prefix cache visible to dispatch —
        enables cache-aware Prefill routing for text-only requests."""
        if name not in self.status:
            raise KeyError(f"unknown instance {name}")
        self.prefix_caches[name] = cache

    def cached_prefix_tokens(self, name: str, req: Request) -> int:
        """Tokens of ``req``'s prompt cached on instance ``name`` (full
        pages only — what a prefill there could actually skip)."""
        cache = self.prefix_caches.get(name)
        if cache is None or req.is_multimodal:
            return 0
        n = cache.match_len(req.prompt_tokens, cap=len(req.prompt_tokens) - 1)
        return (n // cache.page) * cache.page

    def pick(self, stage: str, now: float, prefer: Optional[str] = None,
             req: Optional[Request] = None) -> InstanceStatus:
        """Dispatch an instance serving ``stage``. ``prefer`` pins affinity
        (e.g. keep P and D on the same instance when it serves both).
        For Prefill with registered prefix caches and a text-only ``req``,
        cached-prefix tokens are credited against load at the pending-
        token weight: the longest match wins among comparably loaded
        instances, but never outweighs a deep backlog."""
        cands = [self.status[i.name]
                 for i in self.deployment.stage_instances(stage)
                 if not self.status[i.name].down]
        if not cands:
            raise ValueError(
                f"deployment {self.deployment.name} has no live "
                f"{stage} instance")
        if prefer is not None:
            for c in cands:
                if c.spec.name == prefer:
                    return c
        if (stage == "P" and req is not None and self.prefix_caches
                and self.cache_aware):
            return min(cands, key=lambda c: c.load(now) -
                       PENDING_TOKEN_WEIGHT *
                       self.cached_prefix_tokens(c.spec.name, req))
        return min(cands, key=lambda c: c.load(now))

    # -- status updates (called by the execution layer) --------------------------
    def _retire(self, st: InstanceStatus, tokens: float,
                rid: Optional[str]) -> None:
        """Retire pending tokens, capped by the request's own ledger
        when a ``rid`` is known: retiring more than ``rid`` ever
        enqueued (e.g. on_start(tokens=N) followed by per-chunk
        on_prefill_progress for the same N) cannot drag the aggregate
        below the other requests' outstanding work."""
        if tokens <= 0.0:
            return
        if rid is not None:
            owed = st.pending_by_req.get(rid, 0.0)
            tokens = min(tokens, owed)
            if tokens <= 0.0:
                return
            owed -= tokens
            if owed <= 1e-9:
                st.pending_by_req.pop(rid, None)
            else:
                st.pending_by_req[rid] = owed
        st.pending_tokens = max(0.0, st.pending_tokens - tokens)

    def on_enqueue(self, name: str, tokens: float = 0.0,
                   rid: Optional[str] = None) -> None:
        st = self.status[name]
        st.queue_len += 1
        st.pending_tokens += tokens
        if rid is not None and tokens > 0.0:
            st.pending_by_req[rid] = st.pending_by_req.get(rid, 0.0) + tokens

    def on_start(self, name: str, tokens: float = 0.0,
                 rid: Optional[str] = None) -> None:
        st = self.status[name]
        st.queue_len = max(0, st.queue_len - 1)
        self._retire(st, tokens, rid)

    def on_prefill_progress(self, name: str, tokens: float,
                            rid: Optional[str] = None) -> None:
        """Chunk-granular prefill occupancy: a chunked prefill retires
        its pending tokens one chunk at a time (instead of all at
        start), so the load metric tracks the work actually remaining
        on the instance mid-prefill."""
        self._retire(self.status[name], tokens, rid)

    def on_busy_until(self, name: str, t: float) -> None:
        st = self.status[name]
        st.busy_until = max(st.busy_until, t)

    def on_idle(self, name: str, now: float) -> None:
        """An instance drained its queue at ``now``: collapse any stale
        ``busy_until`` estimate so the load metric returns to ~0 instead
        of biasing pick() away from an idle replica forever (busy_until
        is otherwise only ever max'd upward)."""
        st = self.status[name]
        st.busy_until = min(st.busy_until, now)

    def on_decode_join(self, name: str) -> None:
        self.status[name].active_decode += 1

    def on_decode_leave(self, name: str) -> None:
        st = self.status[name]
        st.active_decode = max(0, st.active_decode - 1)

    def on_instance_down(self, name: str) -> None:
        """The fault plane killed instance ``name``: zero its occupancy
        (its queue, batch, and backlog died with it — the harvested
        requests re-enqueue elsewhere and must not double-count here)
        and mark it down so dispatch never picks it again."""
        st = self.status[name]
        st.down = True
        st.queue_len = 0
        st.active_decode = 0
        st.pending_tokens = 0.0
        st.busy_until = 0.0
        st.pending_by_req.clear()

"""Continuous batching: the iteration-level scheduler (core.batching),
the fused-engine continuous driver (Engine.submit/step/drain_continuous)
and the disaggregated cluster driver (EPDCluster.run_continuous).

The load-bearing property is the PR's hard constraint: continuous-
batched greedy outputs are BIT-IDENTICAL to the serial per-request path
across {paged, prefix_cache, chunked_prefill, preemption, multimodal}
configurations — both drivers execute the same PrefillTask chunk
sequence and the same jitted forwards, so any divergence is a real
scheduling bug, not numerics."""
import jax
import pytest

from repro.configs import get_config
from repro.core.batching import (BatchPlan, IterationScheduler, PrefillJob,
                                 StreamTimeline)
from repro.core.cluster import EPDCluster
from repro.models.model import init_params
from repro.serving.engine import Engine
from repro.serving.request import Request


# ---------------------------------------------------------------------------
# scheduler unit tests (no jax, no engines)
# ---------------------------------------------------------------------------

def _job(n_tokens=32, chunk=16, **kw):
    return PrefillJob(req=Request(prompt_tokens=list(range(n_tokens)),
                                  max_new_tokens=4),
                      n_tokens=n_tokens, chunk=chunk, **kw)


def test_plan_interleaves_round_robin():
    s = IterationScheduler(max_live_prefills=2)
    a, b, c = _job(), _job(), _job()
    for j in (a, b, c):
        s.submit(j)
    p1 = s.plan()
    # live window caps concurrent prefills; both live jobs get a chunk
    assert p1.chunks in ([a, b], [b, a])
    assert c in s.waiting
    p2 = s.plan()
    # round-robin cursor rotates the chunk order across steps
    assert p2.chunks[0] is not p1.chunks[0]


def test_admission_fifo_capped_and_requeue():
    s = IterationScheduler()
    jobs = [_job() for _ in range(3)]
    for j in jobs:
        s.submit(j)
        s.plan()                               # promote to live
    for j in list(s.live):
        j.result = (0, None)
        s.mark_ready(j)
    p = s.plan(free_slots=2)
    assert p.admit == jobs[:2]                 # FIFO, capped at free slots
    assert p.decode                            # an admission decodes this step
    s.requeue_ready(p.admit[0])
    assert s.ready[0] is jobs[0]               # back at the head, no overtake
    assert s.stall_counts["admission"] == 1


def test_barriers_gate_chunks_and_idle_jump():
    s = IterationScheduler()
    late = _job(ready_at=5.0)
    img = _job(feature_ready_at=3.0)
    img.req.mm_payload = b"x"
    img.req.mm_tokens = 8
    img.req.mm_pos = 2                          # run starts inside chunk 0
    txt = _job()
    for j in (late, img, txt):
        s.submit(j)
    p = s.plan(now=0.0)
    assert p.chunks == [txt]
    reasons = dict((id(j), r) for j, r in p.stalled)
    assert reasons[id(late)] == "sync_barrier"
    assert reasons[id(img)] == "feature_barrier"
    p = s.plan(now=5.0)
    assert set(map(id, p.chunks)) == {id(late), id(img), id(txt)}


def test_next_barrier_time_is_idle_jump_target():
    # only barrier-stalled jobs live: the plan comes back empty and the
    # earliest arrival is where the executor jumps the modeled clock
    s = IterationScheduler()
    late = _job(ready_at=5.0)
    img = _job(feature_ready_at=3.0)
    img.req.mm_payload = b"x"
    img.req.mm_tokens = 8
    img.req.mm_pos = 2
    s.submit(late)
    s.submit(img)
    p = s.plan(now=0.0)
    assert p.empty
    assert s.next_barrier_time() == 3.0


def test_pre_image_text_chunks_ignore_feature_barrier():
    # image run starts in chunk 1: chunk 0 (pure text) may run before
    # the feature lands — the E->P barrier is a dependency edge on the
    # overlapping chunk only
    j = _job(n_tokens=32, chunk=16, feature_ready_at=9.0)
    j.req.mm_payload = b"x"
    j.req.mm_tokens = 8
    j.req.mm_pos = 20
    assert j.blocked_reason(now=0.0) is None


def test_chunk_budget_limits_iteration_tokens():
    s = IterationScheduler(max_live_prefills=4, chunk_budget_tokens=20)
    jobs = [_job(chunk=16) for _ in range(3)]
    for j in jobs:
        s.submit(j)
    p = s.plan()
    assert len(p.chunks) == 1                  # 16 fits, 32 would not
    assert any(r == "budget" for _, r in p.stalled)


def test_stream_timeline_fused_vs_streams():
    tl = StreamTimeline()
    tl.charge_prefill(2.0)
    tl.charge_decode(1.0)
    assert tl.makespan == 2.0                  # separate devices: max
    t = tl.charge_decode(1.0, not_before=5.0)  # dependency edge
    assert t == 6.0
    fused = StreamTimeline(fused=True)
    fused.charge_prefill(2.0)
    fused.charge_decode(1.0)
    assert fused.makespan == 3.0               # one device: sum


def test_batch_plan_empty_and_token_count():
    p = BatchPlan(step=1)
    assert p.empty
    p.chunks.append(_job(n_tokens=40, chunk=16))
    assert p.prefill_tokens == 16
    assert not p.empty


# ---------------------------------------------------------------------------
# fused-engine parity matrix: continuous == serial, bit-for-bit
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smollm():
    cfg = get_config("smollm-135m").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


PROMPTS = [list(range(1, 30)), list(range(5, 17)),
           list(range(2, 50)), [7, 8, 9],
           list(range(2, 50)),                 # exact repeat (prefix hit)
           list(range(40, 11, -1))]


def _engine(cfg, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 8)
    return Engine(cfg, params, **kw)


def _serial_outputs(cfg, params, prompts, n=6, **kw):
    eng = _engine(cfg, params, **kw)
    return [eng.run_request(Request(prompt_tokens=p, max_new_tokens=n))
            for p in prompts]


@pytest.mark.parametrize("mode", ["chunked", "prefix", "chunked_prefix",
                                  "chunked_preempt"])
def test_continuous_matches_serial_matrix(smollm, mode):
    cfg, params = smollm
    kw = dict(
        chunked=dict(chunked_prefill=True, prefill_chunk=16),
        prefix=dict(prefix_cache=True),
        chunked_prefix=dict(chunked_prefill=True, prefill_chunk=16,
                            prefix_cache=True),
        chunked_preempt=dict(chunked_prefill=True, prefill_chunk=16,
                             preemption=True,
                             n_pool_pages=1 + 3 * 8),
    )[mode]
    serial = _serial_outputs(cfg, params, PROMPTS, **kw)
    eng = _engine(cfg, params, **kw)
    reqs = [Request(prompt_tokens=p, max_new_tokens=6) for p in PROMPTS]
    for r in reqs:
        eng.submit(r)
    eng.drain_continuous()
    assert [r.output_tokens for r in reqs] == serial
    eng.assert_no_page_leaks()
    assert eng.scheduler.steps > 0
    if mode == "chunked_preempt":
        # the tight pool forces scheduler-driven stalls/preemption at
        # least once — and the audit above proves nothing leaked
        assert (eng.preempt_count > 0
                or eng.scheduler.stall_counts.get("pool", 0) > 0
                or eng.scheduler.stall_counts.get("admission", 0) > 0)


def test_continuous_staggered_arrivals_mid_stream(smollm):
    """Requests submitted while earlier ones are mid-prefill/mid-decode
    (the continuous-batching point) still match the serial outputs."""
    cfg, params = smollm
    kw = dict(chunked_prefill=True, prefill_chunk=16, prefix_cache=True)
    serial = _serial_outputs(cfg, params, PROMPTS, **kw)
    eng = _engine(cfg, params, **kw)
    reqs = [Request(prompt_tokens=p, max_new_tokens=6) for p in PROMPTS]
    for r in reqs[:2]:
        eng.submit(r)
    for _ in range(3):                        # some chunks + admissions run
        eng.step()
    for r in reqs[2:]:                        # late arrivals join mid-stream
        eng.submit(r)
    eng.drain_continuous()
    assert [r.output_tokens for r in reqs] == serial
    eng.assert_no_page_leaks()


def test_mid_drain_leak_audit_under_pressure(smollm):
    """assert_balanced holds at EVERY iteration boundary while the
    scheduler stalls, admits, and preempts against a tight pool —
    in-flight tasks and ready payloads are first-class page holders."""
    cfg, params = smollm
    eng = _engine(cfg, params, max_batch=2, chunked_prefill=True,
                  prefill_chunk=16, preemption=True,
                  n_pool_pages=1 + 4 * 8)
    reqs = [Request(prompt_tokens=p, max_new_tokens=5)
            for p in PROMPTS[:4]]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.scheduler.has_work or eng.n_active or eng.preempted:
        eng.step()
        eng.assert_no_page_leaks()
        steps += 1
        assert steps < 500
    assert all(len(r.output_tokens) == 5 for r in reqs)


# ---------------------------------------------------------------------------
# disaggregated cluster: run_continuous == submit/run_until_done
# ---------------------------------------------------------------------------

def _cluster(cfg, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 8)
    kw.setdefault("chunked_prefill", True)
    kw.setdefault("prefill_chunk", 16)
    return EPDCluster(cfg, params, **kw)


def test_cluster_continuous_matches_serial(smollm):
    cfg, params = smollm
    cl = _cluster(cfg, params, prefix_cache=True)
    reqs = [Request(prompt_tokens=p, max_new_tokens=6) for p in PROMPTS]
    for r in reqs:
        cl.submit(r)
    cl.run_until_done()
    serial = [r.output_tokens for r in reqs]

    cl2 = _cluster(cfg, params, prefix_cache=True)
    reqs2 = [Request(prompt_tokens=p, max_new_tokens=6) for p in PROMPTS]
    done = cl2.run_continuous(reqs2)
    assert [r.output_tokens for r in reqs2] == serial
    assert len(done) == len(reqs2)
    cl2.prefill_engine.assert_no_page_leaks()
    for d in cl2.decode_engines:
        d.assert_no_page_leaks()
    # ground-truth Router: the drained P instance reads idle and its
    # per-request pending ledger fully conserved back to zero
    st = cl2.router.status[cl2.prefill_engine.name]
    assert st.pending_tokens == 0.0
    assert st.pending_by_req == {}
    assert st.load(cl2.continuous_timeline.makespan) == pytest.approx(
        0.0, abs=1e-9)


def test_cluster_continuous_multimodal_text_mix(smollm):
    """VLM + text mix through the full E->P->D loop: the async E->P
    feature barrier is a real dependency edge, yet outputs stay
    bit-identical to the serial driver."""
    cfg = get_config("llava-next-mistral-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))

    def reqs():
        return [Request(prompt_tokens=list(range(1, 20)), max_new_tokens=5,
                        mm_payload=b"imgA", mm_tokens=8, mm_pos=4),
                Request(prompt_tokens=list(range(3, 30)), max_new_tokens=5),
                Request(prompt_tokens=list(range(1, 20)), max_new_tokens=5,
                        mm_payload=b"imgA", mm_tokens=8, mm_pos=4),
                Request(prompt_tokens=list(range(9, 40)), max_new_tokens=4)]

    cl = _cluster(cfg, params, max_batch=2, prefix_cache=True,
                  ep_overlap="async")
    rs = reqs()
    for r in rs:
        cl.submit(r)
    cl.run_until_done()
    serial = [r.output_tokens for r in rs]

    cl2 = _cluster(cfg, params, max_batch=2, prefix_cache=True,
                   ep_overlap="async")
    rs2 = reqs()
    cl2.run_continuous(rs2)
    assert [r.output_tokens for r in rs2] == serial
    cl2.prefill_engine.assert_no_page_leaks()
    for d in cl2.decode_engines:
        d.assert_no_page_leaks()


def test_cluster_continuous_accepts_fault_plans(smollm):
    """The fault-plan guard is gone: run_continuous composes with the
    chaos layer. Under seeded wire loss every request still completes
    bit-identical to the zero-fault run (deeper matrix lives in
    tests/test_batching_faults.py)."""
    cfg, params = smollm
    from repro.core.faults import SITE_TRANSFER_WIRE, FaultPlan
    cl0 = _cluster(cfg, params, prefix_cache=True)
    ref = [Request(prompt_tokens=p, max_new_tokens=6) for p in PROMPTS]
    cl0.run_continuous(ref)

    plan = FaultPlan(seed=7, rates={SITE_TRANSFER_WIRE: 0.3})
    cl = _cluster(cfg, params, prefix_cache=True, faults=plan)
    reqs = [Request(prompt_tokens=p, max_new_tokens=6) for p in PROMPTS]
    done = cl.run_continuous(reqs)
    assert len(done) == len(reqs) and not cl.report.lost
    assert [r.output_tokens for r in reqs] == \
        [r.output_tokens for r in ref]
    cl.prefill_engine.assert_no_page_leaks()
    for d in cl.decode_engines:
        d.assert_no_page_leaks()

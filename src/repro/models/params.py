"""Parameter structure: single source of truth for shapes + logical axes.

``param_structure(cfg)`` returns a pytree of ``ParamSpec``; ``init_params``
materializes it with real values (CPU tests), ``abstract_params`` with
``ShapeDtypeStruct`` (dry-run), and ``param_pspecs`` with PartitionSpec
(jit in_shardings) — all from the same tree, so sharding and shapes can
never drift apart.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.models.partitioning import ShardingRules, logical_to_pspec


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # 'normal' | 'zeros' | 'ones' | 'ssm_a'


def _stack(spec_tree, n: int):
    """Prepend a scanned 'layers' dim to every leaf."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


# -- per-block specs ---------------------------------------------------------

def attn_spec(cfg: ModelConfig, cross: bool = False) -> Dict[str, ParamSpec]:
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    out = {
        "norm": ParamSpec((d,), ("embed",), "ones"),
        "wq": ParamSpec((d, q), ("embed", "q")),
        "wk": ParamSpec((d, kv), ("embed", "kv")),
        "wv": ParamSpec((d, kv), ("embed", "kv")),
        "wo": ParamSpec((q, d), ("q", "embed")),
    }
    if cross:
        out.update({
            "xnorm": ParamSpec((d,), ("embed",), "ones"),
            "xwq": ParamSpec((d, q), ("embed", "q")),
            "xwk": ParamSpec((d, kv), ("embed", "kv")),
            "xwv": ParamSpec((d, kv), ("embed", "kv")),
            "xwo": ParamSpec((q, d), ("q", "embed")),
        })
    return out


def mlp_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "norm": ParamSpec((d,), ("embed",), "ones"),
        "wi": ParamSpec((d, f), ("embed", "ff")),      # up
        "wg": ParamSpec((d, f), ("embed", "ff")),      # gate
        "wo": ParamSpec((f, d), ("ff", "embed")),      # down
    }


def moe_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    return {
        "norm": ParamSpec((d,), ("embed",), "ones"),
        "router": ParamSpec((d, e), ("embed", None)),
        "wi": ParamSpec((e, d, f), ("expert", "embed", "ff")),
        "wg": ParamSpec((e, d, f), ("expert", "embed", "ff")),
        "wo": ParamSpec((e, f, d), ("expert", "ff", "embed")),
    }


def ssm_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    ssm = cfg.ssm
    d = cfg.d_model
    inner = ssm.inner_dim(d)
    nh = ssm.n_heads(d)
    n = ssm.state_dim
    conv_dim = inner + 2 * n      # x, B, C go through the causal conv
    return {
        "norm": ParamSpec((d,), ("embed",), "ones"),
        # in_proj -> [z(inner), xBC(conv_dim), dt(nh)]
        "w_in": ParamSpec((d, 2 * inner + 2 * n + nh), ("embed", "inner")),
        "conv_w": ParamSpec((ssm.conv_width, conv_dim), (None, "inner")),
        "conv_b": ParamSpec((conv_dim,), ("inner",), "zeros"),
        "a_log": ParamSpec((nh,), ("heads",), "ssm_a"),
        "d_skip": ParamSpec((nh,), ("heads",), "ones"),
        "dt_bias": ParamSpec((nh,), ("heads",), "zeros"),
        "out_norm": ParamSpec((inner,), ("inner",), "ones"),
        "w_out": ParamSpec((inner, d), ("inner", "embed")),
    }


def block_spec(cfg: ModelConfig, spec: LayerSpec) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if spec.mixer in ("attn", "swa"):
        out["attn"] = attn_spec(cfg, cross=cfg.encoder is not None)
    elif spec.mixer == "ssm":
        out["ssm"] = ssm_spec(cfg)
    if spec.ffn == "mlp":
        out["mlp"] = mlp_spec(cfg)
    elif spec.ffn == "moe":
        out["moe"] = moe_spec(cfg)
    return out


def encoder_layer_spec(cfg: ModelConfig) -> Dict[str, Any]:
    base = {k: v for k, v in attn_spec(cfg).items()}
    return {"attn": base, "mlp": mlp_spec(cfg)}


# -- whole-model structure ---------------------------------------------------

def param_structure(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    tree: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed")),
        "final_norm": ParamSpec((d,), ("embed",), "ones"),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamSpec((d, cfg.vocab), ("embed", "vocab"))
    # decoder blocks: one stacked entry per pattern position
    tree["blocks"] = [
        _stack(block_spec(cfg, s), cfg.n_repeats) for s in cfg.pattern
    ]
    if cfg.encoder is not None:
        tree["encoder"] = {
            "layers": _stack(encoder_layer_spec(cfg), cfg.encoder.n_layers),
            "final_norm": ParamSpec((d,), ("embed",), "ones"),
            "pos_embed": ParamSpec((cfg.encoder.n_ctx, d), (None, "embed")),
        }
    if cfg.frontend is not None:
        tree["projector"] = ParamSpec(
            (cfg.frontend.feature_dim, d), (None, "embed"))
    return tree


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        param_structure(cfg), is_leaf=_is_spec)


def param_pspecs(cfg: ModelConfig, rules: Optional[ShardingRules]):
    return jax.tree.map(
        lambda s: logical_to_pspec(s.axes, rules),
        param_structure(cfg), is_leaf=_is_spec)


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    """Materialize real parameter values (for CPU-scale configs)."""
    tree = param_structure(cfg)
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))

    def mk(spec: ParamSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        if spec.init == "ssm_a":
            # A in [-1, -e]: log of uniform in [1, e]
            u = jax.random.uniform(k, spec.shape, jnp.float32, 1.0, math.e)
            return jnp.log(u).astype(dtype)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype)

    return jax.tree.unflatten(treedef, [mk(s, k) for s, k in zip(leaves, keys)])


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))

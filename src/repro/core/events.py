"""Minimal discrete-event engine for the EPD serving simulator."""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Optional, Tuple


class EventLoop:
    def __init__(self):
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self.now = 0.0
        # called with each positive clock jump BEFORE the event fires —
        # the telemetry accountant hangs here so every simulated-time
        # advance is charged to the open requests (sum-to-e2e invariant)
        self.on_advance: Optional[Callable[[float], None]] = None

    def at(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (max(t, self.now), next(self._seq), fn))

    def after(self, dt: float, fn: Callable[[], None]) -> None:
        self.at(self.now + dt, fn)

    def run(self, until: float = float("inf")) -> None:
        while self._heap and self._heap[0][0] <= until:
            t, _, fn = heapq.heappop(self._heap)
            dt = t - self.now
            self.now = t
            if dt > 0 and self.on_advance is not None:
                self.on_advance(dt)
            fn()

    def __bool__(self) -> bool:
        return bool(self._heap)

"""Serving launcher: real-compute EPD-disaggregated serving on CPU-scale
configs, or the paper-scale event simulator for any deployment topology.

  # real tensors through the full EPD pipeline (reduced model):
  PYTHONPATH=src python -m repro.launch.serve --arch llava-next-mistral-7b \\
      --requests 8

  # paper-scale simulation of a deployment at a given request rate:
  PYTHONPATH=src python -m repro.launch.serve --simulate --deployment "(E-P)-D" \\
      --rate 8 --arch openpangu-7b-vl
"""
from __future__ import annotations

import argparse

import jax


def run_real(args):
    from repro.configs import get_config
    from repro.core.cluster import EPDCluster
    from repro.models.model import init_params
    from repro.serving.request import Request

    import jax.numpy as jnp
    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    cluster = EPDCluster(cfg, params, max_batch=4, max_len=96,
                         kv_scheme=args.kv_scheme)
    if args.kv_fp8:
        # rebuild engines with fp8 KV storage (§Perf decode optimization)
        from repro.serving.engine import Engine
        cluster.prefill_engine = Engine(cfg, params, max_batch=1,
                                        max_len=96,
                                        kv_dtype=jnp.float8_e4m3fn)
        cluster.decode_engine = Engine(cfg, params, max_batch=4, max_len=96,
                                       kv_dtype=jnp.float8_e4m3fn)
    reqs = []
    for i in range(args.requests):
        mm = (f"image-{i % 3}".encode()
              if cfg.frontend is not None and i % 2 == 0 else None)
        reqs.append(Request(
            prompt_tokens=list(range(2, 2 + 8 + i % 5)),
            max_new_tokens=args.max_new_tokens,
            mm_payload=mm, mm_tokens=8 if mm and cfg.encoder is None else 0))
    for r in reqs:
        cluster.submit(r)
    done = cluster.run_until_done()
    for r in done:
        path = "E-P-D" if r.is_multimodal else "P-D"
        print(f"req {r.request_id} [{path}] -> {r.output_tokens}")
    s = cluster.store.stats
    print(f"MM store: {s} | mean KV overlap: "
          f"{cluster.report.mean_kv_overlap:.3f} | recomputes: "
          f"{cluster.report.recomputes}")


def run_sim(args):
    from repro.configs import get_config
    from repro.core.simulator import SHAREGPT_4O, VISUALWEB, simulate

    ds = SHAREGPT_4O if args.dataset == "sharegpt4o" else VISUALWEB
    model = get_config(args.arch)
    m = simulate(model, args.deployment, ds, rate=args.rate,
                 n_requests=args.requests, kv_scheme=args.kv_scheme,
                 per_chip_rate=args.per_chip_rate)
    print(f"deployment={m.deployment} chips={m.n_chips}")
    print(f"TTFT mean={m.mean_ttft_ms:.1f}ms p99={m.p99_ttft_ms:.1f}ms")
    print(f"TPOT mean={m.mean_tpot_ms:.2f}ms p99={m.p99_tpot_ms:.2f}ms")
    print(f"throughput={m.throughput_tok_s:.1f} tok/s; "
          f"SLO(2000/50)={m.slo_attainment(2000, 50)*100:.1f}%; "
          f"effective={m.effective_throughput(2000, 50):.1f} tok/s/chip")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llava-next-mistral-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--kv-scheme", default="grouped",
                    choices=["one_shot", "layer_wise", "grouped"])
    ap.add_argument("--kv-fp8", action="store_true",
                    help="store KV in fp8_e4m3 (halves decode KV traffic)")
    ap.add_argument("--simulate", action="store_true")
    ap.add_argument("--deployment", default="E-P-D")
    ap.add_argument("--rate", type=float, default=6.0)
    ap.add_argument("--per-chip-rate", action="store_true")
    ap.add_argument("--dataset", default="sharegpt4o",
                    choices=["sharegpt4o", "visualweb"])
    args = ap.parse_args()
    if args.simulate:
        args.requests = max(args.requests, 256)
        run_sim(args)
    else:
        run_real(args)


if __name__ == "__main__":
    main()
